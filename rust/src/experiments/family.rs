//! §Projection family (repo-grown) — one feasibility/identity row per
//! projection operator the crate ships, plus a multilevel tree row.
//!
//! For every flat [`ProjectionKind`] the runner projects the same random
//! matrix at `η = 0.4·‖Y‖` in the kind's own matched norm and reports:
//! feasibility (`‖P(Y)‖ ≤ η`), the identity sum `‖Y−P‖+‖P‖` against
//! `‖Y‖`, and the gap. The identity is exact for the ℓ1,∞ family, ℓ1,1
//! and ℓ2,1 (their projections shrink along the norm); for ℓ1,2 and ℓ∞,1
//! only the triangle inequality `sum ≥ total` is guaranteed, so those
//! rows report the (nonnegative) excess instead of asserting a zero gap.
//!
//! The identity baseline (`ProjectionKind::None`) has **no** matched norm
//! — [`ProjectionKind::matched_norm`] returns `Option::None` — and the
//! report path must render that as an `n/a` row rather than panic; this
//! runner is the regression test for that contract.

use anyhow::Result;

use super::ExpContext;
use crate::norms::frobenius_norm;
use crate::projection::l1::L1Algorithm;
use crate::projection::multilevel::{project_multilevel, tree_norm, MultilevelSpec};
use crate::projection::ProjectionKind;
use crate::report::{markdown_table, CsvWriter};
use crate::rng::Xoshiro256pp;
use crate::tensor::Matrix;

/// The kinds whose matched-norm identity `‖Y−P‖+‖P‖ = ‖Y‖` is exact.
fn identity_is_exact(kind: ProjectionKind) -> bool {
    matches!(
        kind,
        ProjectionKind::BilevelL1Inf
            | ProjectionKind::BilevelL11
            | ProjectionKind::ExactL1InfQuattoni
            | ProjectionKind::ExactL1InfNewton
            | ProjectionKind::ExactL1InfSsn
            | ProjectionKind::L21
    )
}

pub fn family(ctx: &ExpContext) -> Result<()> {
    let (n, m) = if ctx.quick { (40, 30) } else { (200, 300) };
    let mut rng = Xoshiro256pp::seed_from_u64(0xFA);
    let y = Matrix::<f64>::randn(n, m, &mut rng);

    let mut csv = CsvWriter::create(
        "family_projection.csv",
        &["kind", "eta", "norm_before", "norm_after", "resid_norm", "sum", "gap", "feasible"],
    )?;
    let mut rows = Vec::new();

    // Every flat kind plus the identity baseline — the baseline exercises
    // the matched_norm == None report path end to end.
    let mut kinds = ProjectionKind::all().to_vec();
    kinds.push(ProjectionKind::None);
    for kind in kinds {
        match kind.matched_norm(&y) {
            Some(total) => {
                let eta = 0.4 * total;
                let x = kind.apply_with(&y, eta, L1Algorithm::Condat);
                let after = kind.matched_norm(&x).expect("same kind, same Some-ness");
                let resid = kind.matched_norm(&y.sub(&x)).expect("same kind, same Some-ness");
                let sum = after + resid;
                let gap = sum - total;
                let feasible = after <= eta * (1.0 + 1e-9) + 1e-12;
                assert!(feasible, "{}: ‖P(Y)‖ = {after} > η = {eta}", kind.name());
                // Triangle inequality holds for every kind; exactness only
                // for the norms the projection shrinks along.
                assert!(gap >= -1e-8, "{}: sum below total", kind.name());
                if identity_is_exact(kind) {
                    assert!(
                        gap.abs() <= 1e-8 * total.max(1.0),
                        "{}: identity gap {gap:.3e}",
                        kind.name()
                    );
                }
                csv.row(&[
                    kind.name().into(),
                    format!("{eta:.4}"),
                    format!("{total:.6}"),
                    format!("{after:.6}"),
                    format!("{resid:.6}"),
                    format!("{sum:.6}"),
                    format!("{gap:.3e}"),
                    format!("{feasible}"),
                ])?;
                rows.push(vec![
                    kind.name().to_string(),
                    format!("{eta:.2}"),
                    format!("{after:.4}"),
                    format!("{:.2e}", gap.abs()),
                    if identity_is_exact(kind) { "exact".into() } else { "triangle".into() },
                ]);
            }
            Option::None => {
                // The radius-free baseline: P(Y) = Y, no ball, no norm.
                let x = kind.apply_with(&y, 1.0, L1Algorithm::Condat);
                assert_eq!(x.max_abs_diff(&y), 0.0, "baseline must be the identity");
                csv.row(&[
                    kind.name().into(),
                    "n/a".into(),
                    "n/a".into(),
                    "n/a".into(),
                    "n/a".into(),
                    "n/a".into(),
                    "n/a".into(),
                    "true".into(),
                ])?;
                rows.push(vec![
                    kind.name().to_string(),
                    "n/a".into(),
                    format!("{:.4}", frobenius_norm(&x)),
                    "n/a".into(),
                    "identity".into(),
                ]);
            }
        }
    }

    // One multilevel tree row: depth 3, projected onto 40% of its own
    // tree norm, feasibility in the tree norm.
    let spec = MultilevelSpec::parse("l1/l2:8/linf").expect("spec parses");
    let total = tree_norm(&y, &spec);
    let eta = 0.4 * total;
    let x = project_multilevel(&y, eta, &spec);
    let after = tree_norm(&x, &spec);
    assert!(after <= eta * (1.0 + 1e-9) + 1e-12, "multilevel: {after} > {eta}");
    csv.row(&[
        format!("multilevel({})", spec.format()),
        format!("{eta:.4}"),
        format!("{total:.6}"),
        format!("{after:.6}"),
        "n/a".into(),
        "n/a".into(),
        "n/a".into(),
        "true".into(),
    ])?;
    rows.push(vec![
        format!("multilevel({})", spec.format()),
        format!("{eta:.2}"),
        format!("{after:.4}"),
        "n/a".into(),
        "tree".into(),
    ]);

    println!("{}", markdown_table(&["kind", "eta", "‖P(Y)‖", "|gap|", "identity"], &rows));
    println!("family: every kind feasible in its matched norm; baseline row rendered as n/a");
    println!("wrote {}", csv.path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_identity_set_is_a_subset_of_all_kinds() {
        let exact: Vec<_> =
            ProjectionKind::all().iter().copied().filter(|&k| identity_is_exact(k)).collect();
        assert!(exact.contains(&ProjectionKind::BilevelL1Inf));
        assert!(exact.contains(&ProjectionKind::L21));
        assert!(!identity_is_exact(ProjectionKind::BilevelL12));
        assert!(!identity_is_exact(ProjectionKind::Linf1Newton));
        assert!(!identity_is_exact(ProjectionKind::None));
    }
}
