//! Fig. 9 — first-layer weight matrix of the trained SAE: the bilevel
//! projection suppresses whole columns (features), not scattered entries.

use anyhow::Result;

use super::ExpContext;
use crate::config::{DatasetKind, TrainConfig};
use crate::coordinator::SaeTrainer;
use crate::projection::ProjectionKind;
use crate::report::CsvWriter;

pub fn fig9(ctx: &ExpContext) -> Result<()> {
    let rt = ctx.runtime()?;
    let dataset = if ctx.quick { DatasetKind::Tiny } else { DatasetKind::Synth64 };
    let cfg = TrainConfig {
        dataset,
        projection: ProjectionKind::BilevelL1Inf,
        eta: if ctx.quick { 2.0 } else { 2.0 },
        epochs_phase1: if ctx.quick { 4 } else { 15 },
        epochs_phase2: if ctx.quick { 3 } else { 10 },
        ..TrainConfig::default()
    };
    let trainer = SaeTrainer::new(rt, cfg)?;
    let out = trainer.run(ctx.seeds.first().copied().unwrap_or(42))?;
    let d = out.dims;

    // Per-feature max |W1| — the column heights of the paper's Fig. 9.
    let mut csv = CsvWriter::create("fig9_w1_feature_norms.csv", &["feature", "inf_norm", "selected"])?;
    let mut norms = Vec::with_capacity(d.features);
    for f in 0..d.features {
        let row = &out.w1[f * d.hidden..(f + 1) * d.hidden];
        let n = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        norms.push(n);
        csv.row(&[
            f.to_string(),
            format!("{n:.6}"),
            (out.selected_features.contains(&f) as u8).to_string(),
        ])?;
    }

    // Full matrix dump for plotting.
    let mut wcsv = CsvWriter::create("fig9_w1_matrix.csv", &["feature", "hidden", "weight"])?;
    for f in 0..d.features {
        for h in 0..d.hidden {
            let v = out.w1[f * d.hidden + h];
            if v != 0.0 {
                wcsv.row(&[f.to_string(), h.to_string(), format!("{v:.6}")])?;
            }
        }
    }

    // ASCII: column occupancy of the first 100 features.
    let zero_cols = norms.iter().filter(|&&n| n == 0.0).count();
    let shown = d.features.min(100);
    let strip: String = norms[..shown]
        .iter()
        .map(|&n| if n == 0.0 { '.' } else { '#' })
        .collect();
    println!("fig9: W1 is {}x{}; {} of {} feature columns exactly zero ({:.1}%)",
        d.features, d.hidden, zero_cols, d.features,
        100.0 * zero_cols as f64 / d.features as f64);
    println!("fig9: first {shown} features (# = alive, . = suppressed):\n  {strip}");
    println!("fig9: selected features: {:?}", &out.selected_features[..out.selected_features.len().min(32)]);
    println!("wrote {} and {}", csv.path.display(), wcsv.path.display());
    Ok(())
}
