//! Fig. 1 & Fig. 2 — processing time vs matrix size.
//!
//! Fig. 1: `BP¹,∞` (ours, O(nm)) against the Chu et al. semismooth-Newton
//! exact projection (the fastest prior method), sweeping the number of
//! features (n = 1000 samples fixed) and the number of samples (m = 1000
//! features fixed), radius η = 1 as in the paper. A linear curve is fitted
//! to the bi-level timings and an n·log n curve to SSN — the paper's
//! headline "O(log nm)-times faster" claim is the growing ratio.
//!
//! Fig. 2: the three bi-level variants have the same (linear) slope.

use anyhow::Result;

use super::ExpContext;
use crate::bench::{fit_linear, fit_nlogn, time_fn, BenchConfig};
use crate::projection::bilevel::{bilevel_l11, bilevel_l12, bilevel_l1inf};
use crate::projection::l1inf::{project_l1inf, L1InfAlgorithm};
use crate::report::{ascii_chart, markdown_table, CsvWriter};
use crate::rng::Xoshiro256pp;
use crate::tensor::Matrix;

const ETA: f64 = 1.0;

fn sizes(quick: bool) -> Vec<usize> {
    if quick {
        vec![250, 500, 1000, 2000]
    } else {
        vec![500, 1000, 2000, 4000, 8000, 16000]
    }
}

fn bench_cfg(quick: bool) -> BenchConfig {
    if quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

/// Generate the benchmark matrix for a sweep point. `axis` decides whether
/// `size` is the feature count (columns) or sample count (rows).
fn workload(axis: &str, size: usize, seed: u64) -> Matrix<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    match axis {
        "features" => Matrix::randn(1000, size, &mut rng),
        "samples" => Matrix::randn(size, 1000, &mut rng),
        _ => unreachable!(),
    }
}

pub fn fig1(ctx: &ExpContext) -> Result<()> {
    let cfg = bench_cfg(ctx.quick);
    let mut csv = CsvWriter::create(
        "fig1_time.csv",
        &["axis", "size", "bilevel_s", "ssn_s", "ratio"],
    )?;
    let mut summary_rows = Vec::new();

    for axis in ["features", "samples"] {
        let mut xs = Vec::new();
        let mut t_bp = Vec::new();
        let mut t_ssn = Vec::new();
        for &size in &sizes(ctx.quick) {
            let y = workload(axis, size, 0xF16_1 ^ size as u64);
            let s_bp = time_fn(&cfg, || bilevel_l1inf(&y, ETA));
            let s_ssn = time_fn(&cfg, || project_l1inf(&y, ETA, L1InfAlgorithm::Ssn));
            csv.row(&[
                axis.into(),
                size.to_string(),
                format!("{:.6e}", s_bp.median),
                format!("{:.6e}", s_ssn.median),
                format!("{:.3}", s_ssn.median / s_bp.median),
            ])?;
            xs.push(size as f64);
            t_bp.push(s_bp.median);
            t_ssn.push(s_ssn.median);
            println!(
                "fig1 {axis:>8} size {size:>6}: bilevel {:.4} ms, ssn {:.4} ms ({:.1}x)",
                s_bp.median * 1e3,
                s_ssn.median * 1e3,
                s_ssn.median / s_bp.median
            );
        }
        let (a_lin, _, r2_lin) = fit_linear(&xs, &t_bp);
        let (a_nln, _, r2_nln) = fit_nlogn(&xs, &t_ssn);
        // Cross-fits: does the WRONG model fit worse? (the paper's point)
        let (_, _, r2_bp_nlogn) = fit_nlogn(&xs, &t_bp);
        let (_, _, r2_ssn_lin) = fit_linear(&xs, &t_ssn);
        summary_rows.push(vec![
            axis.to_string(),
            format!("{a_lin:.3e}"),
            format!("{r2_lin:.5}"),
            format!("{a_nln:.3e}"),
            format!("{r2_nln:.5}"),
            format!("{:.1}", t_ssn.last().unwrap() / t_bp.last().unwrap()),
        ]);
        println!(
            "fig1 {axis}: bilevel linear fit R2={r2_lin:.5} (nlogn R2={r2_bp_nlogn:.5}); \
             ssn nlogn fit R2={r2_nln:.5} (linear R2={r2_ssn_lin:.5})"
        );
        println!(
            "{}",
            ascii_chart(
                &format!("fig1 time vs {axis} (s)"),
                &xs,
                &[("bilevel", t_bp.clone()), ("ssn", t_ssn.clone())],
                60,
                12,
            )
        );
    }
    let table = markdown_table(
        &["axis", "bilevel slope", "R2(lin)", "ssn slope", "R2(nlogn)", "last-size speedup"],
        &summary_rows,
    );
    println!("{table}");
    crate::report::write_text("fig1_summary.md", &table)?;
    println!("wrote {}", csv.path.display());
    Ok(())
}

pub fn fig2(ctx: &ExpContext) -> Result<()> {
    let cfg = bench_cfg(ctx.quick);
    let mut csv = CsvWriter::create(
        "fig2_bilevel.csv",
        &["axis", "size", "bp_l1inf_s", "bp_l11_s", "bp_l12_s"],
    )?;
    for axis in ["features", "samples"] {
        let mut xs = Vec::new();
        let mut series: [Vec<f64>; 3] = [vec![], vec![], vec![]];
        for &size in &sizes(ctx.quick) {
            let y = workload(axis, size, 0xF16_2 ^ size as u64);
            let t = [
                time_fn(&cfg, || bilevel_l1inf(&y, ETA)).median,
                time_fn(&cfg, || bilevel_l11(&y, ETA)).median,
                time_fn(&cfg, || bilevel_l12(&y, ETA)).median,
            ];
            csv.row(&[
                axis.into(),
                size.to_string(),
                format!("{:.6e}", t[0]),
                format!("{:.6e}", t[1]),
                format!("{:.6e}", t[2]),
            ])?;
            xs.push(size as f64);
            for (s, v) in series.iter_mut().zip(t.iter()) {
                s.push(*v);
            }
            println!(
                "fig2 {axis:>8} size {size:>6}: l1inf {:.3} ms, l11 {:.3} ms, l12 {:.3} ms",
                t[0] * 1e3,
                t[1] * 1e3,
                t[2] * 1e3
            );
        }
        // All three should fit linear with similar slopes (paper: "same
        // slopes").
        for (name, s) in ["bp-l1inf", "bp-l11", "bp-l12"].iter().zip(series.iter()) {
            let (a, _, r2) = fit_linear(&xs, s);
            println!("fig2 {axis} {name}: slope {a:.3e}/elem-col, linear R2 = {r2:.5}");
        }
    }
    println!("wrote {}", csv.path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_shapes() {
        let y = workload("features", 64, 1);
        assert_eq!((y.rows(), y.cols()), (1000, 64));
        let y = workload("samples", 64, 1);
        assert_eq!((y.rows(), y.cols()), (64, 1000));
    }

    #[test]
    fn quick_sizes_are_subset_scale() {
        assert!(sizes(true).len() < sizes(false).len());
        assert!(sizes(true).iter().all(|&s| s <= 2000));
    }
}
