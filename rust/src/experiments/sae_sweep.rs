//! Fig. 7, Fig. 8, Tables II–IV — SAE classification accuracy vs the
//! projection radius η, bilevel vs exact ℓ1,∞ vs no-projection baseline.
//!
//! Each point trains the double-descent SAE through the PJRT artifacts for
//! several seeds and reports accuracy ± std (the paper's format). The
//! tables pick the best radius per method from the sweep and add the
//! baseline row.

use anyhow::Result;

use super::ExpContext;
use crate::config::{DatasetKind, TrainConfig};
use crate::coordinator::run_seeds;
use crate::projection::ProjectionKind;
use crate::report::{ascii_chart, markdown_table, CsvWriter};

/// One sweep point result.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub dataset: &'static str,
    pub method: &'static str,
    pub eta: f64,
    pub mean_acc: f64,
    pub std_acc: f64,
    pub mean_sparsity: f64,
}

fn eta_grid(dataset: DatasetKind, quick: bool) -> Vec<f64> {
    let full: Vec<f64> = match dataset {
        // Paper Fig. 7: best around 0.5 (exact) / 1-2 (bilevel).
        DatasetKind::Synth64 | DatasetKind::Synth16 => {
            vec![0.1, 0.25, 0.5, 1.0, 2.0, 4.0]
        }
        // Paper Fig. 8: radii an order smaller (0.1 / 0.25 best).
        DatasetKind::Hif2 => vec![0.05, 0.1, 0.25, 0.5, 1.0],
        DatasetKind::Tiny => vec![0.5, 1.0, 2.0],
    };
    if quick {
        full.into_iter().step_by(2).collect()
    } else {
        full
    }
}

fn base_cfg(dataset: DatasetKind, quick: bool) -> TrainConfig {
    let (p1, p2) = match (dataset, quick) {
        (DatasetKind::Hif2, false) => (12, 8),
        (DatasetKind::Hif2, true) => (3, 2),
        (_, false) => (15, 10),
        (_, true) => (4, 3),
    };
    TrainConfig {
        dataset,
        epochs_phase1: p1,
        epochs_phase2: p2,
        lr: 1e-3,
        alpha: 1.0,
        ..TrainConfig::default()
    }
}

fn seeds(ctx: &ExpContext) -> Vec<u64> {
    if ctx.quick {
        ctx.seeds.iter().copied().take(2).collect()
    } else {
        ctx.seeds.clone()
    }
}

/// Sweep η for both projection methods on one dataset.
pub fn accuracy_sweep(
    ctx: &ExpContext,
    dataset: DatasetKind,
    ds_label: &'static str,
) -> Result<Vec<SweepPoint>> {
    let rt = ctx.runtime()?;
    let seeds = seeds(ctx);
    let mut out = Vec::new();
    for (method, kind) in [
        ("bilevel-l1inf", ProjectionKind::BilevelL1Inf),
        ("l1inf", ProjectionKind::ExactL1InfSsn),
    ] {
        for &eta in &eta_grid(dataset, ctx.quick) {
            let cfg = TrainConfig { projection: kind, eta, ..base_cfg(dataset, ctx.quick) };
            let s = run_seeds(rt, &cfg, &seeds)?;
            println!(
                "{ds_label} {method:>13} eta={eta:<5}: acc {:.2} ± {:.2} %, sparsity {:.1} %",
                s.mean_accuracy, s.std_accuracy, s.mean_sparsity
            );
            out.push(SweepPoint {
                dataset: ds_label,
                method,
                eta,
                mean_acc: s.mean_accuracy,
                std_acc: s.std_accuracy,
                mean_sparsity: s.mean_sparsity,
            });
        }
    }
    Ok(out)
}

/// Baseline (no projection) accuracy on one dataset.
pub fn baseline(ctx: &ExpContext, dataset: DatasetKind) -> Result<(f64, f64)> {
    let rt = ctx.runtime()?;
    let cfg = TrainConfig {
        projection: ProjectionKind::None,
        ..base_cfg(dataset, ctx.quick)
    };
    let s = run_seeds(rt, &cfg, &seeds(ctx))?;
    Ok((s.mean_accuracy, s.std_accuracy))
}

fn write_sweep_csv(name: &str, points: &[SweepPoint]) -> Result<std::path::PathBuf> {
    let mut csv = CsvWriter::create(
        name,
        &["dataset", "method", "eta", "mean_acc", "std_acc", "mean_sparsity"],
    )?;
    for p in points {
        csv.row(&[
            p.dataset.into(),
            p.method.into(),
            format!("{:.4}", p.eta),
            format!("{:.3}", p.mean_acc),
            format!("{:.3}", p.std_acc),
            format!("{:.3}", p.mean_sparsity),
        ])?;
    }
    Ok(csv.path)
}

fn chart(points: &[SweepPoint], ds: &str) -> String {
    let etas: Vec<f64> = points
        .iter()
        .filter(|p| p.method == "bilevel-l1inf" && p.dataset == ds)
        .map(|p| p.eta)
        .collect();
    let bp: Vec<f64> = points
        .iter()
        .filter(|p| p.method == "bilevel-l1inf" && p.dataset == ds)
        .map(|p| p.mean_acc)
        .collect();
    let ex: Vec<f64> = points
        .iter()
        .filter(|p| p.method == "l1inf" && p.dataset == ds)
        .map(|p| p.mean_acc)
        .collect();
    ascii_chart(
        &format!("{ds}: accuracy(%) vs eta"),
        &etas,
        &[("bilevel", bp), ("exact l1inf", ex)],
        60,
        10,
    )
}

pub fn fig7(ctx: &ExpContext) -> Result<()> {
    let mut all = accuracy_sweep(ctx, DatasetKind::Synth64, "synth64")?;
    all.extend(accuracy_sweep(ctx, DatasetKind::Synth16, "synth16")?);
    let path = write_sweep_csv("fig7_accuracy_vs_eta.csv", &all)?;
    println!("{}", chart(&all, "synth64"));
    println!("{}", chart(&all, "synth16"));
    println!("wrote {}", path.display());
    Ok(())
}

pub fn fig8(ctx: &ExpContext) -> Result<()> {
    let all = accuracy_sweep(ctx, DatasetKind::Hif2, "hif2")?;
    let path = write_sweep_csv("fig8_hif2_accuracy_vs_eta.csv", &all)?;
    println!("{}", chart(&all, "hif2"));
    println!("wrote {}", path.display());
    Ok(())
}

/// Load a previous sweep's points for one dataset from its CSV (lets the
/// tables reuse fig7/fig8 results instead of re-training everything).
fn load_sweep(csv_name: &str, ds_label: &'static str) -> Option<Vec<SweepPoint>> {
    let path = crate::report::results_dir().join(csv_name);
    let (header, rows) = crate::report::read_csv(&path).ok()?;
    if header != ["dataset", "method", "eta", "mean_acc", "std_acc", "mean_sparsity"] {
        return None;
    }
    let mut out = Vec::new();
    for r in rows {
        if r[0] != ds_label {
            continue;
        }
        let method = match r[1].as_str() {
            "bilevel-l1inf" => "bilevel-l1inf",
            "l1inf" => "l1inf",
            _ => continue,
        };
        out.push(SweepPoint {
            dataset: ds_label,
            method,
            eta: r[2].parse().ok()?,
            mean_acc: r[3].parse().ok()?,
            std_acc: r[4].parse().ok()?,
            mean_sparsity: r[5].parse().ok()?,
        });
    }
    (!out.is_empty()).then_some(out)
}

/// Shared table builder (Tables II/III/IV).
fn accuracy_table(
    ctx: &ExpContext,
    dataset: DatasetKind,
    ds_label: &'static str,
    csv_name: &str,
) -> Result<()> {
    let sweep_csv = if dataset == DatasetKind::Hif2 {
        "fig8_hif2_accuracy_vs_eta.csv"
    } else {
        "fig7_accuracy_vs_eta.csv"
    };
    let points = match load_sweep(sweep_csv, ds_label) {
        Some(p) => {
            println!("{csv_name}: reusing sweep results from {sweep_csv}");
            p
        }
        None => accuracy_sweep(ctx, dataset, ds_label)?,
    };
    let (base_acc, base_std) = baseline(ctx, dataset)?;

    let best = |method: &str| -> &SweepPoint {
        points
            .iter()
            .filter(|p| p.method == method)
            .max_by(|a, b| a.mean_acc.partial_cmp(&b.mean_acc).unwrap())
            .expect("sweep produced no points")
    };
    let b_ex = best("l1inf");
    let b_bp = best("bilevel-l1inf");

    let rows = vec![
        vec![
            "Best Radius".into(),
            "-".into(),
            format!("{}", b_ex.eta),
            format!("{}", b_bp.eta),
        ],
        vec![
            "Accuracy %".into(),
            format!("{base_acc:.1} ± {base_std:.1}"),
            format!("{:.1} ± {:.1}", b_ex.mean_acc, b_ex.std_acc),
            format!("{:.1} ± {:.1}", b_bp.mean_acc, b_bp.std_acc),
        ],
        vec![
            "Sparsity %".into(),
            "0".into(),
            format!("{:.1}", b_ex.mean_sparsity),
            format!("{:.1}", b_bp.mean_sparsity),
        ],
    ];
    let table = markdown_table(&[ds_label, "Baseline", "l1inf", "bilevel l1inf"], &rows);
    println!("{table}");
    crate::report::write_text(&format!("{csv_name}.md"), &table)?;

    let mut csv = CsvWriter::create(
        csv_name,
        &["row", "baseline", "l1inf", "bilevel_l1inf"],
    )?;
    csv.row(&[
        "best_radius".into(),
        "".into(),
        format!("{}", b_ex.eta),
        format!("{}", b_bp.eta),
    ])?;
    csv.row(&[
        "mean_acc".into(),
        format!("{base_acc:.3}"),
        format!("{:.3}", b_ex.mean_acc),
        format!("{:.3}", b_bp.mean_acc),
    ])?;
    csv.row(&[
        "std_acc".into(),
        format!("{base_std:.3}"),
        format!("{:.3}", b_ex.std_acc),
        format!("{:.3}", b_bp.std_acc),
    ])?;
    println!("wrote {}", csv.path.display());
    Ok(())
}

pub fn table2(ctx: &ExpContext) -> Result<()> {
    accuracy_table(ctx, DatasetKind::Synth64, "synth64", "table2_synth64.csv")
}

pub fn table3(ctx: &ExpContext) -> Result<()> {
    accuracy_table(ctx, DatasetKind::Synth16, "synth16", "table3_synth16.csv")
}

pub fn table4(ctx: &ExpContext) -> Result<()> {
    accuracy_table(ctx, DatasetKind::Hif2, "hif2", "table4_hif2.csv")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_grids_nonempty_and_positive() {
        for ds in [
            DatasetKind::Synth64,
            DatasetKind::Synth16,
            DatasetKind::Hif2,
            DatasetKind::Tiny,
        ] {
            for quick in [false, true] {
                let g = eta_grid(ds, quick);
                assert!(!g.is_empty());
                assert!(g.iter().all(|&e| e > 0.0));
            }
        }
    }

    #[test]
    fn quick_configs_are_cheaper() {
        let full = base_cfg(DatasetKind::Synth64, false);
        let quick = base_cfg(DatasetKind::Synth64, true);
        assert!(quick.epochs_phase1 < full.epochs_phase1);
    }
}
