//! `sparse` — dense vs compacted structured-sparse encode (the inference
//! workload the paper's column sparsity pays for; companion to `bench
//! sparse`, CSV'd for the results trajectory).
//!
//! Sweeps column-sparsity levels 0–99% for f32/f64 through
//! [`crate::bench::sparse`] and writes `sparse_infer.csv`: per level the
//! alive feature count, both encode medians, the speedup, and whether the
//! compact path reproduced the dense path bit-for-bit (it must — the run
//! errors otherwise).

use anyhow::{anyhow, Result};

use super::ExpContext;
use crate::bench::sparse as sparse_bench;
use crate::report::{markdown_table, CsvWriter};

pub fn sparse(ctx: &ExpContext) -> Result<()> {
    let report = sparse_bench::run(ctx.quick);
    let mut csv = CsvWriter::create(
        "sparse_infer.csv",
        &[
            "dtype", "features", "hidden", "batch", "sparsity_pct", "alive", "dense_s",
            "compact_s", "speedup", "bit_identical",
        ],
    )?;
    let mut rows = Vec::new();
    for e in &report.entries {
        let dtype = e.name.trim_start_matches("encode/");
        csv.row(&[
            dtype.into(),
            e.features.to_string(),
            e.hidden.to_string(),
            e.batch.to_string(),
            e.sparsity_pct.to_string(),
            e.alive.to_string(),
            format!("{:.6e}", e.dense_ms / 1e3),
            format!("{:.6e}", e.compact_ms / 1e3),
            format!("{:.3}", e.speedup()),
            e.bit_identical.to_string(),
        ])?;
        rows.push(vec![
            dtype.to_string(),
            format!("{}x{} b{}", e.features, e.hidden, e.batch),
            format!("{}%", e.sparsity_pct),
            e.alive.to_string(),
            format!("{:.2}x", e.speedup()),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["dtype", "shape", "sparsity", "alive", "speedup"], &rows)
    );
    println!("sparse: wrote {}", csv.path.display());
    if !report.all_bit_identical() {
        return Err(anyhow!("sparse encode diverged bitwise from dense encode"));
    }
    Ok(())
}
