//! Experiment runners: one per table/figure of the paper (DESIGN.md §4).
//!
//! Every runner writes a CSV under `results/` and prints a human-readable
//! summary (markdown table / ASCII chart). `--quick` shrinks sweeps for CI.
//!
//! | id     | paper artifact                                  |
//! |--------|--------------------------------------------------|
//! | fig1   | time vs features/samples, BP¹,∞ vs Chu SSN      |
//! | fig2   | time vs features/samples, three bilevel variants |
//! | fig3   | the ℓ1,∞ identity (Props. III.3/III.5)          |
//! | fig4   | the same curves in the ℓ2,2 norm (inequality)   |
//! | table1 | cumulative sparsity, 4 methods × 2 datasets     |
//! | fig5   | sparsity vs norm-ratio curves, data-64          |
//! | fig6   | sparsity vs norm-ratio curves, data-16          |
//! | fig7   | SAE accuracy vs η, synth-64 & synth-16          |
//! | table2 | synth-64 best-radius accuracy table             |
//! | table3 | synth-16 best-radius accuracy table             |
//! | fig8   | SAE accuracy vs η, HIF2-sim                     |
//! | table4 | HIF2-sim best-radius accuracy table             |
//! | fig9   | first-layer weight sparsity pattern             |
//! | sparse | dense vs compacted sparse encode (repo-grown)   |
//! | family | projection-family feasibility/identity (repo-grown) |

mod family;
mod identity;
mod sae_sweep;
mod sparse_infer;
mod sparsity;
mod timing;
mod weights;

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;

/// Shared context for experiment runners.
pub struct ExpContext {
    /// Shrink sweeps (CI / smoke).
    pub quick: bool,
    /// Seeds for multi-seed aggregation.
    pub seeds: Vec<u64>,
    /// Artifacts directory (SAE experiments need `make artifacts`).
    pub artifacts_dir: String,
    runtime: std::cell::OnceCell<Runtime>,
}

impl ExpContext {
    pub fn new(quick: bool, seeds: Vec<u64>, artifacts_dir: String) -> Self {
        Self { quick, seeds, artifacts_dir, runtime: std::cell::OnceCell::new() }
    }

    /// Lazily-opened PJRT runtime (only the SAE experiments need it).
    pub fn runtime(&self) -> Result<&Runtime> {
        if self.runtime.get().is_none() {
            let rt = Runtime::open(&self.artifacts_dir)?;
            let _ = self.runtime.set(rt);
        }
        Ok(self.runtime.get().unwrap())
    }
}

impl Default for ExpContext {
    fn default() -> Self {
        Self::new(false, vec![42, 43, 44, 45], "artifacts".into())
    }
}

/// All experiment ids in run order. `sparse` and `family` are repo-grown
/// (EXPERIMENTS.md §Sparse inference / §Projection family), the rest map
/// to paper artifacts.
pub const ALL: [&str; 15] = [
    "fig1", "fig2", "fig3", "fig4", "table1", "fig5", "fig6", "fig7", "table2", "table3",
    "fig8", "table4", "fig9", "sparse", "family",
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> Result<()> {
    match id {
        "fig1" => timing::fig1(ctx),
        "fig2" => timing::fig2(ctx),
        "fig3" => identity::fig3(ctx),
        "fig4" => identity::fig4(ctx),
        "table1" => sparsity::table1(ctx),
        "fig5" => sparsity::fig5(ctx),
        "fig6" => sparsity::fig6(ctx),
        "fig7" => sae_sweep::fig7(ctx),
        "table2" => sae_sweep::table2(ctx),
        "table3" => sae_sweep::table3(ctx),
        "fig8" => sae_sweep::fig8(ctx),
        "table4" => sae_sweep::table4(ctx),
        "fig9" => weights::fig9(ctx),
        "sparse" => sparse_infer::sparse(ctx),
        "family" => family::family(ctx),
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        _ => Err(anyhow!("unknown experiment {id:?}; known: {ALL:?} or 'all'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_rejected() {
        let ctx = ExpContext::default();
        assert!(run("nope", &ctx).is_err());
    }

    #[test]
    fn all_ids_distinct() {
        let mut ids = ALL.to_vec();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), ALL.len());
    }
}
