//! Table I, Fig. 5, Fig. 6 — structured sparsity of the projections on the
//! synthetic test matrices.
//!
//! Fig. 5/6 plot the column-sparsity of `P(Y)` against the norm ratio
//! `‖P(Y)‖/‖Y‖` (each method measured in its own norm) as η sweeps the
//! ball radius, for data-64 / data-16 test matrices.
//!
//! Table I's "Cum-Sparsity (%)" aggregates those curves: we compute the
//! area under the sparsity-vs-ratio curve (trapezoidal, ratio ∈ [0,1]) ×
//! 100 — the cumulative sparsity retained across the whole regularisation
//! path. The paper's ordering claim is what must reproduce: bilevel ℓ1,∞ >
//! bilevel ℓ1,1 ≈ bilevel ℓ1,2 ≫ usual ℓ1,∞, and data-64 > data-16.

use anyhow::Result;

use super::ExpContext;
use crate::data::{make_classification, MakeClassificationConfig};
use crate::norms::{column_sparsity, l11_norm, l12_norm, l1inf_norm};
use crate::projection::bilevel::{bilevel_l11, bilevel_l12, bilevel_l1inf};
use crate::projection::l1inf::{project_l1inf, L1InfAlgorithm};
use crate::report::{markdown_table, CsvWriter};
use crate::rng::Xoshiro256pp;
use crate::tensor::Matrix;

type Proj = fn(&Matrix<f64>, f64) -> Matrix<f64>;
type NormFn = fn(&Matrix<f64>) -> f64;

const METHODS: [(&str, Proj, NormFn); 4] = [
    ("bilevel-l1inf", bilevel_l1inf_proj, l1inf_norm::<f64>),
    ("bilevel-l11", bilevel_l11_proj, l11_norm::<f64>),
    ("bilevel-l12", bilevel_l12_proj, l12_norm::<f64>),
    ("l1inf", exact_proj, l1inf_norm::<f64>),
];

fn bilevel_l1inf_proj(y: &Matrix<f64>, eta: f64) -> Matrix<f64> {
    bilevel_l1inf(y, eta)
}
fn bilevel_l11_proj(y: &Matrix<f64>, eta: f64) -> Matrix<f64> {
    bilevel_l11(y, eta)
}
fn bilevel_l12_proj(y: &Matrix<f64>, eta: f64) -> Matrix<f64> {
    bilevel_l12(y, eta)
}
fn exact_proj(y: &Matrix<f64>, eta: f64) -> Matrix<f64> {
    project_l1inf(y, eta, L1InfAlgorithm::Ssn)
}

/// Test matrix (columns = features) for one synthetic dataset.
fn test_matrix(informative: usize, quick: bool) -> Matrix<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(1000 + informative as u64);
    let cfg = MakeClassificationConfig {
        n_samples: if quick { 200 } else { 1000 },
        n_features: if quick { 200 } else { 1000 },
        n_informative: informative,
        ..MakeClassificationConfig::data64()
    };
    let ds = make_classification(&cfg, &mut rng);
    let mut split_rng = Xoshiro256pp::seed_from_u64(2000);
    let split = ds.split(0.2, &mut split_rng);
    let t = &split.test;
    Matrix::from_row_major(
        t.n_samples,
        t.n_features,
        &t.x.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
    )
}

/// One sparsity-vs-ratio curve: returns (ratio, sparsity%) points sorted by
/// ratio, where ratio = ||P(Y)||/||Y|| in the method's own norm.
fn curve(
    y: &Matrix<f64>,
    proj: Proj,
    norm: NormFn,
    points: usize,
) -> Vec<(f64, f64, f64)> {
    let total = norm(y);
    let mut out = Vec::new();
    for i in 1..=points {
        // Log-spaced etas cover the interesting low-ratio regime densely.
        let frac = (i as f64 / points as f64).powi(2);
        let eta = total * frac;
        let x = proj(y, eta);
        let ratio = norm(&x) / total;
        let sp = column_sparsity(&x, 1e-12) * 100.0;
        out.push((eta, ratio, sp));
    }
    out
}

/// Trapezoidal area under sparsity(ratio)/100 over ratio in [0, 1], ×100.
fn cum_sparsity(points: &[(f64, f64, f64)]) -> f64 {
    // Sort by ratio, prepend (0, 100) (eta=0 ⇒ everything zero), append
    // (1, s_last≈0).
    let mut pts: Vec<(f64, f64)> = points.iter().map(|&(_, r, s)| (r, s)).collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut area = 0.0;
    let mut prev = (0.0, 100.0);
    for &(r, s) in &pts {
        area += (r - prev.0) * 0.5 * (s + prev.1);
        prev = (r, s);
    }
    if prev.0 < 1.0 {
        area += (1.0 - prev.0) * 0.5 * prev.1; // decay to 0 at ratio 1
    }
    area / 100.0 * 100.0 // normalised percent
}

fn sparsity_figure(ctx: &ExpContext, informative: usize, csv_name: &str) -> Result<()> {
    let y = test_matrix(informative, ctx.quick);
    let points = if ctx.quick { 8 } else { 24 };
    let mut csv = CsvWriter::create(csv_name, &["method", "eta", "ratio", "sparsity_pct"])?;
    for (name, proj, norm) in METHODS {
        for (eta, ratio, sp) in curve(&y, proj, norm, points) {
            csv.row(&[
                name.into(),
                format!("{eta:.5}"),
                format!("{ratio:.5}"),
                format!("{sp:.3}"),
            ])?;
        }
        println!("{csv_name}: {name} curve done");
    }
    println!("wrote {}", csv.path.display());
    Ok(())
}

pub fn fig5(ctx: &ExpContext) -> Result<()> {
    sparsity_figure(ctx, 64, "fig5_sparsity_data64.csv")
}

pub fn fig6(ctx: &ExpContext) -> Result<()> {
    sparsity_figure(ctx, 16, "fig6_sparsity_data16.csv")
}

pub fn table1(ctx: &ExpContext) -> Result<()> {
    let points = if ctx.quick { 8 } else { 24 };
    let mut csv = CsvWriter::create("table1_cum_sparsity.csv", &["dataset", "method", "cum_sparsity_pct"])?;
    let mut rows = Vec::new();
    let mut values = std::collections::HashMap::new();
    for (ds_name, informative) in [("data-64", 64usize), ("data-16", 16usize)] {
        let y = test_matrix(informative, ctx.quick);
        let mut row = vec![ds_name.to_string()];
        for (name, proj, norm) in METHODS {
            let c = curve(&y, proj, norm, points);
            let cum = cum_sparsity(&c);
            csv.row(&[ds_name.into(), name.into(), format!("{cum:.3}")])?;
            row.push(format!("{cum:.2}"));
            values.insert((ds_name, name), cum);
        }
        rows.push(row);
    }
    let table = markdown_table(
        &["Cum-Sparsity (%)", "bilevel l1inf", "bilevel l11", "bilevel l12", "l1inf"],
        &rows,
    );
    println!("{table}");
    crate::report::write_text("table1_summary.md", &table)?;

    // The paper's ordering claims (Table I):
    for ds in ["data-64", "data-16"] {
        let bp = values[&(ds, "bilevel-l1inf")];
        let exact = values[&(ds, "l1inf")];
        println!(
            "table1 {ds}: bilevel-l1inf {bp:.2}% vs exact l1inf {exact:.2}% => bilevel wins: {}",
            bp > exact
        );
    }
    println!("wrote {}", csv.path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cum_sparsity_of_step_function() {
        // sparsity 100% until ratio 0.5, then 0 → area = 0.5*100 + small.
        let pts = vec![(0.1, 0.5, 100.0), (0.2, 0.5001, 0.0)];
        let c = cum_sparsity(&pts);
        assert!((c - 50.0).abs() < 1.0, "{c}");
    }

    #[test]
    fn curve_is_monotone_in_eta() {
        let y = test_matrix(8, true);
        let c = curve(&y, bilevel_l1inf_proj, l1inf_norm::<f64>, 6);
        // ratio increases with eta; sparsity decreases.
        for w in c.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "ratio not monotone");
            assert!(w[1].2 <= w[0].2 + 1e-9, "sparsity not antitone");
        }
    }

    #[test]
    fn bilevel_beats_exact_in_cum_sparsity_quick() {
        let y = test_matrix(8, true);
        let bp = cum_sparsity(&curve(&y, bilevel_l1inf_proj, l1inf_norm::<f64>, 8));
        let ex = cum_sparsity(&curve(&y, exact_proj, l1inf_norm::<f64>, 8));
        assert!(bp >= ex, "bilevel {bp} should be >= exact {ex}");
    }
}
