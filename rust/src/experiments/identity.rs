//! Fig. 3 & Fig. 4 — experimental verification of the ℓ1,∞ identity.
//!
//! Fig. 3 (Prop. III.3 / III.5): for both `BP¹,∞` and the exact `P¹,∞`,
//! `‖Y − P(Y)‖₁,∞ + ‖P(Y)‖₁,∞ = ‖Y‖₁,∞` exactly, for every η — the two
//! curves coincide with the line `‖Y‖₁,∞`.
//!
//! Fig. 4 (Remark V.1): measured with the *mismatched* ℓ2,2 norm the sum
//! strictly exceeds `‖Y‖₂,₂` (triangle inequality), and the exact
//! projection has the lower ℓ2,2 error (it IS the Euclidean projection).
//!
//! `Y` is the test matrix of the paper's data-64 synthetic dataset,
//! columns = features, as in §V.B.

use anyhow::Result;

use super::ExpContext;
use crate::data::{make_classification, MakeClassificationConfig};
use crate::norms::{frobenius_norm, l1inf_norm};
use crate::projection::bilevel::bilevel_l1inf;
use crate::projection::l1inf::{project_l1inf, L1InfAlgorithm};
use crate::report::{markdown_table, CsvWriter};
use crate::rng::Xoshiro256pp;
use crate::tensor::Matrix;

/// Test matrix of data-64 (paper §V.B): 200 held-out samples × 1000
/// features (columns = features in our column-major Matrix).
fn test_matrix(quick: bool) -> Matrix<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(64);
    let cfg = if quick {
        MakeClassificationConfig {
            n_samples: 100,
            n_features: 100,
            n_informative: 16,
            ..MakeClassificationConfig::data64()
        }
    } else {
        MakeClassificationConfig::data64()
    };
    let ds = make_classification(&cfg, &mut rng);
    let mut split_rng = Xoshiro256pp::seed_from_u64(65);
    let split = ds.split(0.2, &mut split_rng);
    let t = &split.test;
    Matrix::from_row_major(
        t.n_samples,
        t.n_features,
        &t.x.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
    )
}

fn eta_grid(total: f64, points: usize) -> Vec<f64> {
    (1..=points).map(|i| total * i as f64 / points as f64 * 0.45).collect()
}

pub fn fig3(ctx: &ExpContext) -> Result<()> {
    let y = test_matrix(ctx.quick);
    let total = l1inf_norm(&y);
    let mut csv = CsvWriter::create(
        "fig3_identity.csv",
        &["eta", "method", "norm_proj", "norm_resid", "sum", "total", "gap"],
    )?;
    let mut max_gap: f64 = 0.0;
    let mut rows = Vec::new();
    for eta in eta_grid(total, if ctx.quick { 6 } else { 16 }) {
        for (name, x) in [
            ("bilevel", bilevel_l1inf(&y, eta)),
            ("exact", project_l1inf(&y, eta, L1InfAlgorithm::Ssn)),
        ] {
            let np = l1inf_norm(&x);
            let nr = l1inf_norm(&y.sub(&x));
            let gap = (np + nr - total).abs();
            max_gap = max_gap.max(gap / total);
            csv.row(&[
                format!("{eta:.4}"),
                name.into(),
                format!("{np:.6}"),
                format!("{nr:.6}"),
                format!("{:.6}", np + nr),
                format!("{total:.6}"),
                format!("{gap:.3e}"),
            ])?;
            rows.push(vec![
                format!("{eta:.2}"),
                name.to_string(),
                format!("{:.4}", np + nr),
                format!("{total:.4}"),
                format!("{gap:.2e}"),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(&["eta", "method", "‖P‖+‖Y−P‖ (l1inf)", "‖Y‖ (l1inf)", "gap"], &rows)
    );
    println!("fig3: max relative identity gap = {max_gap:.3e} (expected ~1e-12 in f64)");
    println!("wrote {}", csv.path.display());
    assert!(max_gap < 1e-9, "identity violated!");
    Ok(())
}

pub fn fig4(ctx: &ExpContext) -> Result<()> {
    let y = test_matrix(ctx.quick);
    let total_l1inf = l1inf_norm(&y);
    let total_f = frobenius_norm(&y);
    let mut csv = CsvWriter::create(
        "fig4_l22.csv",
        &["eta", "method", "norm_proj_l22", "resid_l22", "sum_l22", "total_l22"],
    )?;
    let mut exact_always_lower = true;
    for eta in eta_grid(total_l1inf, if ctx.quick { 6 } else { 16 }) {
        let bp = bilevel_l1inf(&y, eta);
        let ex = project_l1inf(&y, eta, L1InfAlgorithm::Ssn);
        let mut resids = Vec::new();
        for (name, x) in [("bilevel", &bp), ("exact", &ex)] {
            let np = frobenius_norm(x);
            let nr = frobenius_norm(&y.sub(x));
            resids.push(nr);
            csv.row(&[
                format!("{eta:.4}"),
                name.into(),
                format!("{np:.6}"),
                format!("{nr:.6}"),
                format!("{:.6}", np + nr),
                format!("{total_f:.6}"),
            ])?;
            // Triangle inequality in the mismatched norm: sum >= total.
            assert!(
                np + nr >= total_f - 1e-9,
                "l2,2 sum below total: {} < {total_f}",
                np + nr
            );
        }
        if resids[1] > resids[0] + 1e-9 {
            exact_always_lower = false;
        }
    }
    println!(
        "fig4: identity does NOT hold in l2,2 (sum > total, as expected); \
         exact projection has lower l2,2 error at every eta: {exact_always_lower}"
    );
    println!("wrote {}", csv.path.display());
    assert!(exact_always_lower, "exact projection must minimise l2 error");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_grid_monotone_positive() {
        let g = eta_grid(100.0, 5);
        assert_eq!(g.len(), 5);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(g[0] > 0.0);
    }

    #[test]
    fn quick_test_matrix_shape() {
        let y = test_matrix(true);
        assert_eq!(y.cols(), 100); // features are columns
        assert_eq!(y.rows(), 20); // 20% of 100 samples
    }
}
