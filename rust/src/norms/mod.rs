//! Matrix norms used throughout the paper.
//!
//! Notation follows the paper (§II): for `Y ∈ R^{n×m}` with columns `y_j`,
//!
//! * `‖Y‖₁,∞  = Σ_j max_i |Y_ij|`   — eq. (1), the structured-sparsity norm;
//! * `‖Y‖∞,₁  = max_j Σ_i |Y_ij|`   — eq. (4), its dual;
//! * `‖Y‖₁,₁  = Σ_j Σ_i |Y_ij|`;
//! * `‖Y‖₁,₂  = Σ_j ‖y_j‖₂`        — the group-lasso norm;
//! * `‖Y‖_F   = ‖Y‖₂,₂`.
//!
//! The first index is the *outer* (aggregation over columns) norm, the
//! second the *inner* (within-column) norm.

use crate::scalar::Scalar;
use crate::tensor::{vec_ops, Matrix};

/// `‖Y‖₁,∞ = Σ_j ‖y_j‖∞` (paper eq. 1).
pub fn l1inf_norm<T: Scalar>(y: &Matrix<T>) -> T {
    y.columns().map(vec_ops::linf).sum()
}

/// `‖Y‖∞,₁ = max_j ‖y_j‖₁` (paper eq. 4, the dual norm).
pub fn linf1_norm<T: Scalar>(y: &Matrix<T>) -> T {
    y.columns()
        .map(vec_ops::l1)
        .fold(T::ZERO, |acc, v| acc.max_s(v))
}

/// `‖Y‖₁,₁ = Σ_ij |Y_ij|`.
pub fn l11_norm<T: Scalar>(y: &Matrix<T>) -> T {
    y.as_slice().iter().map(|&x| x.abs()).sum()
}

/// `‖Y‖₁,₂ = Σ_j ‖y_j‖₂` (group-lasso norm).
pub fn l12_norm<T: Scalar>(y: &Matrix<T>) -> T {
    y.columns().map(vec_ops::l2).sum()
}

/// `‖Y‖₂,₁ = Σ_i ‖Y_{i,:}‖₂` — sum of *row* ℓ2 norms (the group-lasso
/// norm over rows, matched to the ℓ2,1-ball projection). Row sums of
/// squares are accumulated column-by-column so the column-major storage
/// is walked contiguously.
pub fn l21_norm<T: Scalar>(y: &Matrix<T>) -> T {
    let mut sumsq = vec![T::ZERO; y.rows()];
    for col in y.columns() {
        for (acc, &v) in sumsq.iter_mut().zip(col.iter()) {
            *acc = *acc + v * v;
        }
    }
    sumsq.into_iter().map(|s| s.sqrt()).sum()
}

/// Frobenius norm `‖Y‖₂,₂`.
pub fn frobenius_norm<T: Scalar>(y: &Matrix<T>) -> T {
    y.as_slice().iter().map(|&x| x * x).sum::<T>().sqrt()
}

/// Row vector of column ∞-norms `v_∞ = (‖y₁‖∞, …, ‖y_m‖∞)` (§III.A).
pub fn column_linf<T: Scalar>(y: &Matrix<T>) -> Vec<T> {
    y.columns().map(vec_ops::linf).collect()
}

/// Row vector of column ℓ1 norms `v₁` (§IV.A).
pub fn column_l1<T: Scalar>(y: &Matrix<T>) -> Vec<T> {
    y.columns().map(vec_ops::l1).collect()
}

/// Row vector of column ℓ2 norms `v₂` (§IV.B).
pub fn column_l2<T: Scalar>(y: &Matrix<T>) -> Vec<T> {
    y.columns().map(vec_ops::l2).collect()
}

/// Fraction of all-zero columns (the paper's structured "sparsity score").
pub fn column_sparsity<T: Scalar>(y: &Matrix<T>, tol: T) -> f64 {
    if y.cols() == 0 {
        return 0.0;
    }
    y.zero_columns(tol).len() as f64 / y.cols() as f64
}

/// Fraction of zero entries (unstructured sparsity).
pub fn entry_sparsity<T: Scalar>(y: &Matrix<T>, tol: T) -> f64 {
    if y.is_empty() {
        return 0.0;
    }
    y.count_zeros(tol) as f64 / y.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn sample() -> Matrix<f64> {
        // columns: [1, -2], [0, 0], [3, 4]
        Matrix::from_row_major(2, 3, &[1.0, 0.0, 3.0, -2.0, 0.0, 4.0])
    }

    #[test]
    fn l1inf_is_sum_of_col_maxima() {
        assert_eq!(l1inf_norm(&sample()), 2.0 + 0.0 + 4.0);
    }

    #[test]
    fn linf1_is_max_of_col_sums() {
        assert_eq!(linf1_norm(&sample()), 7.0);
    }

    #[test]
    fn l11_and_l12() {
        let y = sample();
        assert_eq!(l11_norm(&y), 10.0);
        assert_eq!(l12_norm(&y), 5.0f64.sqrt() + 0.0 + 5.0);
    }

    #[test]
    fn l21_is_sum_of_row_l2_norms() {
        // rows: [1, 0, 3], [-2, 0, 4]
        let y = sample();
        assert!((l21_norm(&y) - (10.0f64.sqrt() + 20.0f64.sqrt())).abs() < 1e-12);
        assert_eq!(l21_norm(&Matrix::<f64>::zeros(0, 0)), 0.0);
    }

    #[test]
    fn frobenius() {
        assert_eq!(frobenius_norm(&sample()), (1.0f64 + 4.0 + 9.0 + 16.0).sqrt());
    }

    #[test]
    fn column_norm_vectors() {
        let y = sample();
        assert_eq!(column_linf(&y), vec![2.0, 0.0, 4.0]);
        assert_eq!(column_l1(&y), vec![3.0, 0.0, 7.0]);
        assert_eq!(column_l2(&y), vec![5.0f64.sqrt(), 0.0, 5.0]);
    }

    #[test]
    fn sparsity_scores() {
        let y = sample();
        assert!((column_sparsity(&y, 0.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((entry_sparsity(&y, 0.0) - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn duality_inequality_holds() {
        // |<X,Y>| <= ||X||_{1,inf} * ||Y||_{inf,1} on random draws.
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        for _ in 0..20 {
            let x = Matrix::<f64>::randn(8, 5, &mut rng);
            let y = Matrix::<f64>::randn(8, 5, &mut rng);
            let inner: f64 = x
                .as_slice()
                .iter()
                .zip(y.as_slice().iter())
                .map(|(&a, &b)| a * b)
                .sum();
            assert!(inner.abs() <= l1inf_norm(&x) * linf1_norm(&y) + 1e-9);
        }
    }

    #[test]
    fn empty_matrix_norms() {
        let y = Matrix::<f64>::zeros(0, 0);
        assert_eq!(l1inf_norm(&y), 0.0);
        assert_eq!(frobenius_norm(&y), 0.0);
        assert_eq!(column_sparsity(&y, 0.0), 0.0);
    }
}
