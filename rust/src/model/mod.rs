//! SAE parameter handling on the Rust side.
//!
//! Mirrors `python/compile/model.py` exactly: parameter order
//! `w1, b1, w2, b2, w3, b3, w4, b4`, shapes from [`SaeDims`]. Weights are
//! stored row-major (PJRT literal layout); `w1` of shape `(features,
//! hidden)` reinterprets zero-copy as a **column-major `(hidden,
//! features)` matrix** whose columns are features — exactly what the
//! native projection library consumes.

use crate::rng::{Normal, Rng};
use crate::scalar::Scalar;
use crate::tensor::Matrix;

pub const PARAM_NAMES: [&str; 8] = ["w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4"];

/// Static SAE dimensions (must match the AOT preset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SaeDims {
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl SaeDims {
    /// Shapes in PARAM_NAMES order.
    pub fn shapes(&self) -> [Vec<usize>; 8] {
        let (f, h, k) = (self.features, self.hidden, self.classes);
        [
            vec![f, h],
            vec![h],
            vec![h, k],
            vec![k],
            vec![k, h],
            vec![h],
            vec![h, f],
            vec![f],
        ]
    }
}

/// Flat parameter set (8 tensors, row-major).
#[derive(Clone, Debug)]
pub struct SaeParams {
    pub dims: SaeDims,
    pub tensors: Vec<Vec<f32>>,
}

impl SaeParams {
    /// He-normal weight init (std = sqrt(2 / fan_in)), zero biases — the
    /// PyTorch-default-adjacent init the paper's SAE uses.
    pub fn init<R: Rng + ?Sized>(dims: SaeDims, rng: &mut R) -> Self {
        let mut normal = Normal::standard();
        let tensors = dims
            .shapes()
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                if shape.len() == 2 {
                    let std = (2.0 / shape[0] as f64).sqrt();
                    (0..n).map(|_| (normal.sample(rng) * std) as f32).collect()
                } else {
                    vec![0.0f32; n]
                }
            })
            .collect();
        Self { dims, tensors }
    }

    /// All-zero tensors of the same shapes (Adam moment buffers).
    pub fn zeros_like(&self) -> Self {
        Self {
            dims: self.dims,
            tensors: self.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect(),
        }
    }

    /// Replace the 8 tensors from decomposed PJRT outputs (f32 host vecs).
    pub fn set_from(&mut self, tensors: Vec<Vec<f32>>) {
        assert_eq!(tensors.len(), 8);
        for (mine, theirs) in self.tensors.iter_mut().zip(tensors) {
            assert_eq!(mine.len(), theirs.len(), "param size changed");
            *mine = theirs;
        }
    }

    /// W1 `(features, hidden)` row-major == `(hidden, features)`
    /// column-major: columns are features. Zero-copy clone of the data.
    pub fn w1_as_feature_columns(&self) -> Matrix<f32> {
        let d = self.dims;
        Matrix::from_col_major(d.hidden, d.features, self.tensors[0].clone())
    }

    /// Write back a matrix produced by [`Self::w1_as_feature_columns`].
    pub fn set_w1_from_feature_columns(&mut self, m: Matrix<f32>) {
        let d = self.dims;
        assert_eq!((m.rows(), m.cols()), (d.hidden, d.features));
        self.tensors[0] = m.into_vec();
    }

    /// Per-feature infinity norms of W1 (feature importance scores).
    pub fn feature_scores(&self) -> Vec<f64> {
        let d = self.dims;
        let w1 = &self.tensors[0];
        (0..d.features)
            .map(|f| {
                w1[f * d.hidden..(f + 1) * d.hidden]
                    .iter()
                    .fold(0.0f32, |m, &v| m.max(v.abs())) as f64
            })
            .collect()
    }

    /// Zero the rows of W1 for masked-out features (mask in {0,1}).
    pub fn apply_feature_mask(&mut self, mask: &[f32]) {
        let d = self.dims;
        assert_eq!(mask.len(), d.features);
        for (f, &m) in mask.iter().enumerate() {
            if m == 0.0 {
                self.tensors[0][f * d.hidden..(f + 1) * d.hidden].fill(0.0);
            }
        }
    }

    /// Features currently alive (non-zero W1 row).
    pub fn alive_features(&self) -> usize {
        self.feature_scores().iter().filter(|&&s| s > 0.0).count()
    }

    /// % of features entirely zeroed — the paper's sparsity score.
    pub fn sparsity_percent(&self) -> f64 {
        let d = self.dims;
        100.0 * (d.features - self.alive_features()) as f64 / d.features as f64
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Feature `f`'s encoder weights: row `f` of the `(features, hidden)`
    /// row-major W1 (== column `f` of the projection's column-major view).
    pub fn w1_row(&self, f: usize) -> &[f32] {
        let h = self.dims.hidden;
        &self.tensors[0][f * h..(f + 1) * h]
    }
}

/// Column mask from projection thresholds: feature stays iff `u_f > tol`.
pub fn mask_from_thresholds<T: Scalar>(u: &[T], tol: T) -> Vec<f32> {
    u.iter().map(|&v| if v > tol { 1.0 } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn dims() -> SaeDims {
        SaeDims { features: 20, hidden: 6, classes: 2 }
    }

    #[test]
    fn shapes_match_python_convention() {
        let s = dims().shapes();
        assert_eq!(s[0], vec![20, 6]); // w1
        assert_eq!(s[2], vec![6, 2]); // w2
        assert_eq!(s[4], vec![2, 6]); // w3
        assert_eq!(s[6], vec![6, 20]); // w4
        assert_eq!(s[7], vec![20]); // b4
    }

    #[test]
    fn init_scales_with_fan_in() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let p = SaeParams::init(SaeDims { features: 1000, hidden: 100, classes: 2 }, &mut rng);
        let w1 = &p.tensors[0];
        let var: f64 =
            w1.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / w1.len() as f64;
        assert!((var - 2.0 / 1000.0).abs() < 4e-4, "w1 var {var}");
        assert!(p.tensors[1].iter().all(|&b| b == 0.0));
        assert_eq!(p.n_params(), 1000 * 100 + 100 + 100 * 2 + 2 + 2 * 100 + 100 + 100 * 1000 + 1000);
    }

    #[test]
    fn w1_feature_columns_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut p = SaeParams::init(dims(), &mut rng);
        let m = p.w1_as_feature_columns();
        assert_eq!((m.rows(), m.cols()), (6, 20));
        // column f == row f of the row-major (F,H) tensor
        for f in 0..20 {
            assert_eq!(m.col(f), &p.tensors[0][f * 6..(f + 1) * 6]);
        }
        let m2 = m.map(|v| v * 2.0);
        p.set_w1_from_feature_columns(m2);
        assert_eq!(p.tensors[0][0], 2.0 * m.col(0)[0]);
    }

    #[test]
    fn mask_zeroes_rows_and_scores() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut p = SaeParams::init(dims(), &mut rng);
        let mut mask = vec![1.0f32; 20];
        for f in 0..5 {
            mask[f] = 0.0;
        }
        p.apply_feature_mask(&mask);
        let scores = p.feature_scores();
        assert!(scores[..5].iter().all(|&s| s == 0.0));
        assert!(scores[5..].iter().all(|&s| s > 0.0));
        assert_eq!(p.alive_features(), 15);
        assert!((p.sparsity_percent() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn mask_from_thresholds_tolerance() {
        let u = [0.0f64, 1e-12, 0.5];
        assert_eq!(mask_from_thresholds(&u, 1e-9), vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn set_from_validates_shapes() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let mut p = SaeParams::init(dims(), &mut rng);
        let clone = p.tensors.clone();
        p.set_from(clone);
    }
}
