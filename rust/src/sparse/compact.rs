//! Structural compaction of a trained SAE: drop pruned features from the
//! parameter tensors, with an exact decompaction back to the original
//! index space.
//!
//! In the [`crate::model`] layout the feature dimension appears in exactly
//! three tensors:
//!
//! * `w1 (features, hidden)` row-major — feature `f` is the contiguous
//!   **row** `f` (equivalently: column `f` of the `(hidden, features)`
//!   column-major view the projection zeroes);
//! * `w4 (hidden, features)` row-major — feature `f` is the strided
//!   **column** `f` of the decoder;
//! * `b4 (features)` — the decoder bias entry.
//!
//! [`compact_params`] keeps only the [`CompactPlan`]'s alive slices of
//! those three (bitwise copies) and leaves the five feature-free tensors
//! untouched, producing a genuine [`SaeParams`] with
//! `dims.features == plan.alive()` — every existing accessor
//! (`feature_scores`, `n_params`, `w1_as_feature_columns`, …) works on the
//! compacted model in compact index space. [`decompact_params`] is the
//! exact inverse on alive features (zeros elsewhere), so reports keep
//! speaking original feature indices.
//!
//! [`CompactEncoder`] freezes the first (encoder) layer of a compacted
//! model for inference — the unit the serve engine registers and the
//! `bilevel sparsify` CLI measures.

use crate::model::{SaeDims, SaeParams};
use crate::scalar::Scalar;
use crate::tensor::Matrix;

use super::linalg;
use super::support::CompactPlan;

/// Drop pruned features from `p` according to `plan`. Alive slices are
/// copied bitwise; `plan.features()` must match `p.dims.features`.
pub fn compact_params(p: &SaeParams, plan: &CompactPlan) -> SaeParams {
    let d = p.dims;
    assert_eq!(
        plan.features(),
        d.features,
        "compact_params: plan features != model features"
    );
    let (h, a) = (d.hidden, plan.alive());
    let dims = SaeDims { features: a, hidden: d.hidden, classes: d.classes };

    // w1: keep alive rows of the (F, H) row-major tensor.
    let mut w1 = Vec::with_capacity(a * h);
    for &f in plan.alive_indices() {
        w1.extend_from_slice(p.w1_row(f));
    }
    // w4 (H, F) row-major: keep alive entries of every row.
    let w4_src = &p.tensors[6];
    let mut w4 = Vec::with_capacity(h * a);
    for i in 0..h {
        for &f in plan.alive_indices() {
            w4.push(w4_src[i * d.features + f]);
        }
    }
    // b4: keep alive entries.
    let b4: Vec<f32> = plan.alive_indices().iter().map(|&f| p.tensors[7][f]).collect();

    let tensors = vec![
        w1,
        p.tensors[1].clone(),
        p.tensors[2].clone(),
        p.tensors[3].clone(),
        p.tensors[4].clone(),
        p.tensors[5].clone(),
        w4,
        b4,
    ];
    SaeParams { dims, tensors }
}

/// Exact inverse of [`compact_params`]: scatter the compacted tensors back
/// to the original feature space, zero-filling pruned features.
pub fn decompact_params(c: &SaeParams, plan: &CompactPlan) -> SaeParams {
    let d = c.dims;
    assert_eq!(
        plan.alive(),
        d.features,
        "decompact_params: plan alive != compact features"
    );
    let (h, m) = (d.hidden, plan.features());
    let dims = SaeDims { features: m, hidden: d.hidden, classes: d.classes };

    let mut w1 = vec![0.0f32; m * h];
    for (compact, &f) in plan.alive_indices().iter().enumerate() {
        w1[f * h..(f + 1) * h].copy_from_slice(&c.tensors[0][compact * h..(compact + 1) * h]);
    }
    let mut w4 = vec![0.0f32; h * m];
    for i in 0..h {
        for (compact, &f) in plan.alive_indices().iter().enumerate() {
            w4[i * m + f] = c.tensors[6][i * d.features + compact];
        }
    }
    let mut b4 = vec![0.0f32; m];
    for (compact, &f) in plan.alive_indices().iter().enumerate() {
        b4[f] = c.tensors[7][compact];
    }

    let tensors = vec![
        w1,
        c.tensors[1].clone(),
        c.tensors[2].clone(),
        c.tensors[3].clone(),
        c.tensors[4].clone(),
        c.tensors[5].clone(),
        w4,
        b4,
    ];
    SaeParams { dims, tensors }
}

/// A frozen, compacted first layer — the structured-sparse inference unit.
///
/// Holds the compacted `(alive, hidden)` encoder weights, the bias, and
/// the plan mapping back to original feature indices. `encode*` runs the
/// column-support kernels of [`super::linalg`]: inputs stay in the
/// **original** feature space (shape `(features, batch)`, one sample per
/// column), cost scales with `alive()`.
#[derive(Clone, Debug)]
pub struct CompactEncoder<T: Scalar> {
    plan: CompactPlan,
    hidden: usize,
    /// `(alive, hidden)` row-major compacted encoder weights.
    w1c: Vec<T>,
    b1: Vec<T>,
}

impl<T: Scalar> CompactEncoder<T> {
    /// Extract the encoder of a **dense** model, compacting it under
    /// `plan` (weights cast from the model's f32 storage).
    pub fn from_params(p: &SaeParams, plan: &CompactPlan) -> Self {
        let d = p.dims;
        assert_eq!(
            plan.features(),
            d.features,
            "CompactEncoder: plan features != model features"
        );
        let h = d.hidden;
        let mut w1c = Vec::with_capacity(plan.alive() * h);
        for &f in plan.alive_indices() {
            w1c.extend(p.w1_row(f).iter().map(|&v| T::from_f64(v as f64)));
        }
        let b1 = p.tensors[1].iter().map(|&v| T::from_f64(v as f64)).collect();
        Self { plan: plan.clone(), hidden: h, w1c, b1 }
    }

    /// Extract the encoder of an **already compacted** model (e.g. a
    /// loaded [`crate::persist::Checkpoint`]'s bundle): `c.tensors[0]` is
    /// the `(alive, hidden)` encoder verbatim, so this is bit-identical
    /// to [`Self::from_params`] on the dense model `c` was compacted
    /// from — `compact_params` copies alive W1 rows bitwise and both
    /// paths apply the same `f32 → T` cast.
    pub fn from_compact(c: &SaeParams, plan: &CompactPlan) -> Self {
        let d = c.dims;
        assert_eq!(
            plan.alive(),
            d.features,
            "CompactEncoder: plan alive != compact features"
        );
        let w1c = c.tensors[0].iter().map(|&v| T::from_f64(v as f64)).collect();
        let b1 = c.tensors[1].iter().map(|&v| T::from_f64(v as f64)).collect();
        Self { plan: plan.clone(), hidden: d.hidden, w1c, b1 }
    }

    pub fn plan(&self) -> &CompactPlan {
        &self.plan
    }

    /// Original feature count an input batch must have (rows of `x`).
    pub fn features(&self) -> usize {
        self.plan.features()
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn alive(&self) -> usize {
        self.plan.alive()
    }

    /// Compacted weights, `(alive, hidden)` row-major.
    pub fn w1c(&self) -> &[T] {
        &self.w1c
    }

    pub fn b1(&self) -> &[T] {
        &self.b1
    }

    /// Batch sparse encode into a reusable output (`(hidden, batch)`).
    pub fn encode_into(&self, x: &Matrix<T>, out: &mut Matrix<T>) {
        assert_eq!(x.rows(), self.features(), "CompactEncoder: input rows != features");
        linalg::encode_batch_compact_into(x, &self.w1c, &self.b1, self.hidden, &self.plan, out);
    }

    /// Batch sparse encode (allocating form).
    pub fn encode(&self, x: &Matrix<T>) -> Matrix<T> {
        let mut out = Matrix::zeros(0, 0);
        self.encode_into(x, &mut out);
        out
    }

    /// 64-bit content fingerprint (weights, bias, plan) — a stable
    /// identity for logging / deduplicating encoders across processes.
    /// (The serve engine keys its registry by a cheap engine-local
    /// sequential id, not this hash.)
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut step = |v: u64| h = (h ^ v).wrapping_mul(PRIME);
        step(self.plan.features() as u64);
        step(self.hidden as u64);
        for &f in self.plan.alive_indices() {
            step(f as u64);
        }
        for &w in &self.w1c {
            step(w.to_f64().to_bits());
        }
        for &b in &self.b1 {
            step(b.to_f64().to_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SaeDims;
    use crate::rng::Xoshiro256pp;

    fn masked_params(seed: u64, kill: &[usize]) -> (SaeParams, CompactPlan) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut p = SaeParams::init(SaeDims { features: 12, hidden: 5, classes: 3 }, &mut rng);
        let mut mask = vec![1.0f32; 12];
        for &f in kill {
            mask[f] = 0.0;
        }
        p.apply_feature_mask(&mask);
        (p, CompactPlan::from_mask(&mask))
    }

    #[test]
    fn compact_shapes_and_param_count() {
        let (p, plan) = masked_params(1, &[0, 4, 5, 11]);
        let c = compact_params(&p, &plan);
        assert_eq!(c.dims.features, 8);
        assert_eq!(c.dims.hidden, 5);
        assert_eq!(c.dims.classes, 3);
        let shapes = c.dims.shapes();
        for (t, s) in c.tensors.iter().zip(shapes.iter()) {
            assert_eq!(t.len(), s.iter().product::<usize>());
        }
        // dropped: 4 rows of w1 (4*5), 4 cols of w4 (5*4), 4 entries of b4
        assert_eq!(p.n_params() - c.n_params(), 4 * 5 + 5 * 4 + 4);
        assert_eq!(c.alive_features(), 8);
    }

    #[test]
    fn compact_copies_alive_slices_bitwise() {
        let (p, plan) = masked_params(2, &[1, 7]);
        let c = compact_params(&p, &plan);
        let (h, m) = (p.dims.hidden, p.dims.features);
        for (compact, &f) in plan.alive_indices().iter().enumerate() {
            for k in 0..h {
                assert_eq!(
                    c.tensors[0][compact * h + k].to_bits(),
                    p.tensors[0][f * h + k].to_bits(),
                    "w1 row {f}"
                );
            }
            for i in 0..h {
                assert_eq!(
                    c.tensors[6][i * plan.alive() + compact].to_bits(),
                    p.tensors[6][i * m + f].to_bits(),
                    "w4 col {f}"
                );
            }
            assert_eq!(c.tensors[7][compact].to_bits(), p.tensors[7][f].to_bits());
        }
        // feature-free tensors untouched
        for t in [1usize, 2, 3, 4, 5] {
            assert_eq!(c.tensors[t], p.tensors[t]);
        }
    }

    #[test]
    fn decompact_roundtrip_identity_on_alive_zero_elsewhere() {
        let (p, plan) = masked_params(3, &[0, 2, 3, 9, 10]);
        let back = decompact_params(&compact_params(&p, &plan), &plan);
        assert_eq!(back.dims, p.dims);
        let (h, m) = (p.dims.hidden, p.dims.features);
        for f in 0..m {
            if plan.is_alive(f) {
                for k in 0..h {
                    assert_eq!(
                        back.tensors[0][f * h + k].to_bits(),
                        p.tensors[0][f * h + k].to_bits(),
                        "w1 row {f}"
                    );
                }
                for i in 0..h {
                    assert_eq!(
                        back.tensors[6][i * m + f].to_bits(),
                        p.tensors[6][i * m + f].to_bits(),
                        "w4 col {f}"
                    );
                }
                assert_eq!(back.tensors[7][f].to_bits(), p.tensors[7][f].to_bits());
            } else {
                // Pruned features come back zero everywhere. NOTE: the
                // mask only zeroes W1 rows, so p's dead W4 columns / b4
                // entries may be non-zero — decompact is the identity on
                // the *support*, not on weights the plan dropped.
                assert!(back.tensors[0][f * h..(f + 1) * h].iter().all(|&v| v == 0.0));
                assert!((0..h).all(|i| back.tensors[6][i * m + f] == 0.0));
                assert_eq!(back.tensors[7][f], 0.0);
            }
        }
        // feature-free tensors round-trip untouched
        for t in [1usize, 2, 3, 4, 5] {
            assert_eq!(back.tensors[t], p.tensors[t]);
        }
    }

    #[test]
    fn extreme_plans_roundtrip() {
        // 100% dead and 0% dead.
        let (p, _) = masked_params(4, &[]);
        let all = CompactPlan::dense(12);
        let c = compact_params(&p, &all);
        assert_eq!(c.n_params(), p.n_params());
        assert_eq!(decompact_params(&c, &all).tensors, p.tensors);

        let none = CompactPlan::from_mask(&[0.0f32; 12]);
        let mut dead = p.clone();
        dead.apply_feature_mask(&none.mask());
        let c0 = compact_params(&dead, &none);
        assert_eq!(c0.dims.features, 0);
        assert_eq!(c0.tensors[0].len(), 0);
        let back = decompact_params(&c0, &none);
        assert_eq!(back.dims.features, 12);
        assert!(back.tensors[0].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn encoder_matches_dense_encode_bitwise() {
        let (p, plan) = masked_params(5, &[1, 2, 6, 8]);
        let enc = CompactEncoder::<f32>::from_params(&p, &plan);
        assert_eq!(enc.alive(), 8);
        assert_eq!(enc.features(), 12);
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let x = Matrix::<f32>::randn(12, 4, &mut rng);
        let sparse = enc.encode(&x);
        let mut dense = Matrix::zeros(0, 0);
        super::linalg::encode_batch_dense_into(
            &x,
            &p.tensors[0],
            &p.tensors[1],
            p.dims.hidden,
            &mut dense,
        );
        assert_eq!((sparse.rows(), sparse.cols()), (5, 4));
        for (a, b) in sparse.as_slice().iter().zip(dense.as_slice().iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn from_compact_matches_from_params_bitwise() {
        let (p, plan) = masked_params(9, &[0, 2, 5, 9]);
        let c = compact_params(&p, &plan);
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let x = Matrix::<f64>::randn(12, 3, &mut rng);
        let via_dense = CompactEncoder::<f64>::from_params(&p, &plan);
        let via_compact = CompactEncoder::<f64>::from_compact(&c, &plan);
        assert_eq!(via_dense.fingerprint(), via_compact.fingerprint());
        let (a, b) = (via_dense.encode(&x), via_compact.encode(&x));
        for (u, v) in a.as_slice().iter().zip(b.as_slice().iter()) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        // f32 cast path agrees too
        assert_eq!(
            CompactEncoder::<f32>::from_params(&p, &plan).fingerprint(),
            CompactEncoder::<f32>::from_compact(&c, &plan).fingerprint()
        );
    }

    #[test]
    fn fingerprint_sensitive_to_weights_and_plan() {
        let (p, plan) = masked_params(7, &[3]);
        let enc = CompactEncoder::<f64>::from_params(&p, &plan);
        assert_eq!(enc.fingerprint(), CompactEncoder::<f64>::from_params(&p, &plan).fingerprint());
        let (p2, plan2) = masked_params(7, &[4]);
        let enc2 = CompactEncoder::<f64>::from_params(&p2, &plan2);
        assert_ne!(enc.fingerprint(), enc2.fingerprint());
    }
}
