//! Column support sets: which features survived the projection, and the
//! compact ↔ original index mapping everything downstream shares.
//!
//! The bi-level projection's structured sparsity lands as zero *columns*
//! of the encoder weights (`û_j = 0` ⇒ feature `j` dead, Remark III.2).
//! A [`CompactPlan`] freezes that pattern: the ordered list of alive
//! original indices (the compact→original map) plus the inverse lookup,
//! so compacted models, sparse kernels, and reports can all speak both
//! index spaces without re-deriving anything.

use crate::model::mask_from_thresholds;
use crate::scalar::Scalar;

/// Frozen support set of a structured-sparse model: maps compact slots
/// (`0..alive`) to original feature indices and back.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompactPlan {
    /// Original feature count (the dense model's `m`).
    features: usize,
    /// Alive original indices, strictly increasing; `alive[c]` is the
    /// original index of compact slot `c`.
    alive: Vec<usize>,
    /// Inverse map: `compact_of[f] = Some(c)` iff original feature `f`
    /// occupies compact slot `c`.
    compact_of: Vec<Option<usize>>,
}

impl CompactPlan {
    /// Build from a {0,1} feature mask (the trainer's mask convention:
    /// `mask[f] > 0` ⇔ feature `f` alive).
    pub fn from_mask(mask: &[f32]) -> Self {
        let alive: Vec<usize> = mask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m > 0.0)
            .map(|(f, _)| f)
            .collect();
        Self::from_alive(mask.len(), alive)
    }

    /// Build from the bi-level per-column thresholds `û` (feature alive iff
    /// `û_f > tol` — the same rule as [`mask_from_thresholds`]).
    pub fn from_thresholds<T: Scalar>(u: &[T], tol: T) -> Self {
        Self::from_mask(&mask_from_thresholds(u, tol))
    }

    /// Build from an explicit strictly-increasing alive list.
    pub fn from_alive(features: usize, alive: Vec<usize>) -> Self {
        let mut compact_of = vec![None; features];
        for w in alive.windows(2) {
            assert!(w[0] < w[1], "CompactPlan: alive indices must be strictly increasing");
        }
        for (c, &f) in alive.iter().enumerate() {
            assert!(f < features, "CompactPlan: alive index {f} out of range {features}");
            compact_of[f] = Some(c);
        }
        Self { features, alive, compact_of }
    }

    /// The dense plan: every feature alive (0% sparsity).
    pub fn dense(features: usize) -> Self {
        Self::from_alive(features, (0..features).collect())
    }

    /// Original feature count.
    pub fn features(&self) -> usize {
        self.features
    }

    /// Number of alive features (the compacted model's feature count).
    pub fn alive(&self) -> usize {
        self.alive.len()
    }

    /// Alive original indices, strictly increasing (compact → original).
    pub fn alive_indices(&self) -> &[usize] {
        &self.alive
    }

    /// Original index of compact slot `c`.
    pub fn original_of(&self, c: usize) -> usize {
        self.alive[c]
    }

    /// Compact slot of original feature `f`, `None` if it was pruned.
    pub fn compact_of(&self, f: usize) -> Option<usize> {
        self.compact_of[f]
    }

    /// Whether original feature `f` survived.
    pub fn is_alive(&self, f: usize) -> bool {
        self.compact_of[f].is_some()
    }

    /// The trainer's {0,1} mask for this support set.
    pub fn mask(&self) -> Vec<f32> {
        let mut mask = vec![0.0f32; self.features];
        for &f in &self.alive {
            mask[f] = 1.0;
        }
        mask
    }

    /// % of features pruned — the paper's structured sparsity score.
    pub fn sparsity_percent(&self) -> f64 {
        if self.features == 0 {
            return 0.0;
        }
        100.0 * (self.features - self.alive.len()) as f64 / self.features as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_mask_roundtrips_indices() {
        let mask = [1.0f32, 0.0, 0.0, 1.0, 1.0, 0.0];
        let plan = CompactPlan::from_mask(&mask);
        assert_eq!(plan.features(), 6);
        assert_eq!(plan.alive(), 3);
        assert_eq!(plan.alive_indices(), &[0, 3, 4]);
        assert_eq!(plan.original_of(1), 3);
        assert_eq!(plan.compact_of(3), Some(1));
        assert_eq!(plan.compact_of(2), None);
        assert!(plan.is_alive(4) && !plan.is_alive(5));
        assert_eq!(plan.mask(), mask.to_vec());
        assert!((plan.sparsity_percent() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn from_thresholds_matches_mask_rule() {
        let u = [0.0f64, 1e-12, 0.5, 2.0];
        let plan = CompactPlan::from_thresholds(&u, 1e-9);
        assert_eq!(plan.alive_indices(), &[2, 3]);
        // the trainer's exact-zero rule
        let plan0 = CompactPlan::from_thresholds(&u, 0.0);
        assert_eq!(plan0.alive_indices(), &[1, 2, 3]);
    }

    #[test]
    fn dense_and_empty_extremes() {
        let dense = CompactPlan::dense(4);
        assert_eq!(dense.alive(), 4);
        assert_eq!(dense.sparsity_percent(), 0.0);
        let empty = CompactPlan::from_mask(&[0.0; 4]);
        assert_eq!(empty.alive(), 0);
        assert_eq!(empty.sparsity_percent(), 100.0);
        let none = CompactPlan::from_mask(&[]);
        assert_eq!(none.features(), 0);
        assert_eq!(none.sparsity_percent(), 0.0);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn unsorted_alive_rejected() {
        CompactPlan::from_alive(4, vec![2, 1]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_alive_rejected() {
        CompactPlan::from_alive(4, vec![4]);
    }
}
