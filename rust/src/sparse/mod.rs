//! # `sparse` — structured-sparse inference subsystem
//!
//! The paper's point (§V) is that the bi-level ℓ1,∞ projection zeroes
//! *entire columns* of the encoder weights — structured sparsity that a
//! dense matvec then ignores completely. This subsystem closes that loop:
//! everything downstream of a projection can now *exploit* the killed
//! columns instead of multiplying by them.
//!
//! * [`support`] — [`CompactPlan`]: the frozen support set derived from
//!   the bi-level thresholds `û` (via
//!   [`crate::model::mask_from_thresholds`]), mapping alive features ↔
//!   original indices.
//! * [`compact`] — [`compact_params`] / [`decompact_params`]: structurally
//!   remove pruned features from a trained [`crate::model::SaeParams`]
//!   (alive slices copied bitwise; the round-trip back to original
//!   indices is exact on the support, pruned features come back zero)
//!   and [`CompactEncoder`], the frozen compacted first layer.
//! * [`linalg`] — column-support matvec / SpMM encode kernels routed
//!   through the lane-chunked [`crate::kernels`] layer (`axpy` rows), with
//!   a scalar reference pinned bit-identical PR-2 style. Encode cost
//!   scales with **alive** features, not the original `m`; the dense and
//!   sparse paths are bit-identical on pruned models (see the
//!   [`linalg`] module docs for the `-0.0`-free accumulator argument).
//!
//! Wiring: [`crate::coordinator::TrainOutcome`] carries a compacted model
//! + plan, the serve engine accepts a sparse-encode job kind running a
//! registered [`CompactEncoder`], the `bilevel sparsify` CLI demonstrates
//! the project → plan → compact → verify → time pipeline, and
//! `bilevel bench sparse` / `cargo bench --bench sparse_infer` write
//! `BENCH_sparse.json` (dense vs compacted encode across sparsity levels;
//! see EXPERIMENTS.md §Sparse inference).

pub mod compact;
pub mod linalg;
pub mod support;

pub use compact::{compact_params, decompact_params, CompactEncoder};
pub use support::CompactPlan;
