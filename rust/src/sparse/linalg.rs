//! Column-support matvec / SpMM kernels — the structured-sparse encoder.
//!
//! The encoder's hot loop is `h = xᵀ·W1 + b1` with `W1` stored `(features,
//! hidden)` row-major (the [`crate::model`] convention): feature `f`'s
//! weights are the contiguous row `w1[f·H .. (f+1)·H]`. That layout makes
//! the matvec a sequence of row [`kernels::axpy`] updates — and makes
//! column-structured sparsity *skippable*: a pruned feature's row never
//! has to be read. Cost scales with the number of **alive** features, not
//! the original `m`.
//!
//! Three entry points share one accumulation recipe:
//!
//! * [`encode_dense_into`] — every row, in index order (the dense
//!   baseline);
//! * [`encode_support_into`] — an explicit strictly-increasing support
//!   list over the *dense* weights (skip-dead, no compaction);
//! * [`encode_compact_into`] — compacted weights `(alive, hidden)` plus a
//!   [`CompactPlan`] gathering the matching input entries.
//!
//! **Bit-identity.** All three produce bit-identical outputs on a model
//! whose pruned rows are exactly zero and finite inputs, at every sparsity
//! level including 0% and 100%, because:
//!
//! 1. the accumulator starts at `+0.0` and the bias is added **last** —
//!    an IEEE-754 sum is `-0.0` only when *both* addends are `-0.0`, so
//!    no intermediate accumulator is ever `-0.0`;
//! 2. a pruned row contributes only `x_f · (±0.0) = ±0.0` terms, and
//!    adding `±0.0` to an accumulator that is not `-0.0` returns it
//!    unchanged, bit for bit;
//! 3. alive rows are visited in the same (increasing) order with the same
//!    bits by all three paths, and [`kernels::axpy`] applies the same
//!    per-element `acc + a·row` (no `mul_add` fusion).
//!
//! So the dense path's extra (dead-row) axpys are exact no-ops, and every
//! per-element rounding step agrees. `rust/tests/sparse_integration.rs`
//! pins this for f32/f64 across sparsity levels; [`encode_dense_into_ref`]
//! is the scalar reference the chunked paths are pinned against, PR-2
//! style.

use crate::kernels;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

use super::support::CompactPlan;

/// Shared epilogue: add the bias last (see the module docs — load-bearing
/// for the `-0.0`-free accumulator argument).
#[inline]
fn add_bias<T: Scalar>(out: &mut [T], b1: &[T]) {
    debug_assert_eq!(out.len(), b1.len());
    for (o, &b) in out.iter_mut().zip(b1) {
        *o += b;
    }
}

/// Dense encode of one sample: `out = xᵀ·W1 + b1`, iterating **all**
/// feature rows. `w1` is `(features, hidden)` row-major.
pub fn encode_dense_into<T: Scalar>(
    x: &[T],
    w1: &[T],
    b1: &[T],
    hidden: usize,
    out: &mut [T],
) {
    assert_eq!(out.len(), hidden, "encode: out length != hidden");
    assert_eq!(b1.len(), hidden, "encode: bias length != hidden");
    assert_eq!(w1.len(), x.len() * hidden, "encode: W1 shape mismatch");
    out.fill(T::ZERO);
    for (f, row) in w1.chunks_exact(hidden.max(1)).enumerate() {
        kernels::axpy(out, x[f], row);
    }
    add_bias(out, b1);
}

/// Scalar reference for the encode recipe (naive loops, same term order).
pub fn encode_dense_into_ref<T: Scalar>(
    x: &[T],
    w1: &[T],
    b1: &[T],
    hidden: usize,
    out: &mut [T],
) {
    assert_eq!(out.len(), hidden, "encode_ref: out length != hidden");
    assert_eq!(b1.len(), hidden, "encode_ref: bias length != hidden");
    assert_eq!(w1.len(), x.len() * hidden, "encode_ref: W1 shape mismatch");
    out.fill(T::ZERO);
    for (f, row) in w1.chunks_exact(hidden.max(1)).enumerate() {
        kernels::axpy_ref(out, x[f], row);
    }
    add_bias(out, b1);
}

/// Support-set encode over **dense** weights: only the rows named by
/// `support` (strictly increasing original indices) are read.
pub fn encode_support_into<T: Scalar>(
    x: &[T],
    w1: &[T],
    b1: &[T],
    hidden: usize,
    support: &[usize],
    out: &mut [T],
) {
    assert_eq!(out.len(), hidden, "encode_support: out length != hidden");
    assert_eq!(b1.len(), hidden, "encode_support: bias length != hidden");
    assert_eq!(w1.len(), x.len() * hidden, "encode_support: W1 shape mismatch");
    out.fill(T::ZERO);
    for &f in support {
        kernels::axpy(out, x[f], &w1[f * hidden..(f + 1) * hidden]);
    }
    add_bias(out, b1);
}

/// Compact encode: `w1c` is the compacted `(alive, hidden)` row-major
/// weights; inputs are gathered from the **original** index space through
/// the plan (`x` keeps its full length).
pub fn encode_compact_into<T: Scalar>(
    x: &[T],
    w1c: &[T],
    b1: &[T],
    hidden: usize,
    plan: &CompactPlan,
    out: &mut [T],
) {
    assert_eq!(out.len(), hidden, "encode_compact: out length != hidden");
    assert_eq!(b1.len(), hidden, "encode_compact: bias length != hidden");
    assert_eq!(x.len(), plan.features(), "encode_compact: input length != plan features");
    assert_eq!(w1c.len(), plan.alive() * hidden, "encode_compact: W1c shape mismatch");
    out.fill(T::ZERO);
    for (row, &f) in w1c.chunks_exact(hidden.max(1)).zip(plan.alive_indices()) {
        kernels::axpy(out, x[f], row);
    }
    add_bias(out, b1);
}

/// Batch (SpMM) forms: `x` is `(features, batch)` column-major (each
/// column one sample — the [`Matrix`] layout keeps samples contiguous),
/// `out` becomes `(hidden, batch)`.
pub fn encode_batch_dense_into<T: Scalar>(
    x: &Matrix<T>,
    w1: &[T],
    b1: &[T],
    hidden: usize,
    out: &mut Matrix<T>,
) {
    out.resize_reuse(hidden, x.cols());
    for j in 0..x.cols() {
        encode_dense_into(x.col(j), w1, b1, hidden, out.col_mut(j));
    }
}

/// Batch form of [`encode_compact_into`].
pub fn encode_batch_compact_into<T: Scalar>(
    x: &Matrix<T>,
    w1c: &[T],
    b1: &[T],
    hidden: usize,
    plan: &CompactPlan,
    out: &mut Matrix<T>,
) {
    out.resize_reuse(hidden, x.cols());
    for j in 0..x.cols() {
        encode_compact_into(x.col(j), w1c, b1, hidden, plan, out.col_mut(j));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn assert_bits_eq<T: Scalar>(a: &[T], b: &[T], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_f64().to_bits(),
                y.to_f64().to_bits(),
                "{what}: element {i}: {x} vs {y}"
            );
        }
    }

    /// Weights with the rows outside `alive` exactly zeroed.
    fn masked_weights(features: usize, hidden: usize, alive: &[usize], seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut w1: Vec<f64> =
            (0..features * hidden).map(|_| rng.uniform(-2.0, 2.0)).collect();
        for f in 0..features {
            if !alive.contains(&f) {
                w1[f * hidden..(f + 1) * hidden].fill(0.0);
            }
        }
        w1
    }

    #[test]
    fn chunked_encode_bit_identical_to_scalar_ref() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for (features, hidden) in [(1usize, 1usize), (7, 5), (16, 8), (33, 17)] {
            let w1: Vec<f64> =
                (0..features * hidden).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let b1: Vec<f64> = (0..hidden).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..features).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let mut a = vec![0.0; hidden];
            let mut b = vec![0.0; hidden];
            encode_dense_into(&x, &w1, &b1, hidden, &mut a);
            encode_dense_into_ref(&x, &w1, &b1, hidden, &mut b);
            assert_bits_eq(&a, &b, "dense vs ref");
        }
    }

    #[test]
    fn support_and_compact_match_dense_bitwise() {
        let (features, hidden) = (24usize, 10usize);
        for alive in [
            (0..features).collect::<Vec<_>>(), // 0% sparsity
            vec![0, 3, 4, 11, 23],
            vec![1],
            vec![], // 100% sparsity
        ] {
            let w1 = masked_weights(features, hidden, &alive, 42);
            let mut rng = Xoshiro256pp::seed_from_u64(43);
            let b1: Vec<f64> = (0..hidden).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..features).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let plan = CompactPlan::from_alive(features, alive.clone());
            let w1c: Vec<f64> = alive
                .iter()
                .flat_map(|&f| w1[f * hidden..(f + 1) * hidden].to_vec())
                .collect();
            let mut dense = vec![0.0; hidden];
            let mut supp = vec![0.0; hidden];
            let mut comp = vec![0.0; hidden];
            encode_dense_into(&x, &w1, &b1, hidden, &mut dense);
            encode_support_into(&x, &w1, &b1, hidden, &alive, &mut supp);
            encode_compact_into(&x, &w1c, &b1, hidden, &plan, &mut comp);
            assert_bits_eq(&dense, &supp, "support vs dense");
            assert_bits_eq(&dense, &comp, "compact vs dense");
        }
    }

    #[test]
    fn negative_zero_rows_cannot_flip_bits() {
        // Projection-killed rows can hold -0.0 (clip at û=0 of a negative
        // entry); the accumulator argument must survive that.
        let (features, hidden) = (4usize, 3usize);
        let mut w1 = vec![0.0f64; features * hidden];
        w1[0..3].copy_from_slice(&[-0.0, -0.0, -0.0]); // dead row of -0.0
        w1[3..6].copy_from_slice(&[1.0, -2.0, 0.5]); // alive
        w1[6..9].copy_from_slice(&[0.0, -0.0, 0.0]); // dead, mixed zeros
        w1[9..12].copy_from_slice(&[-1.0, 4.0, -0.25]); // alive
        let b1 = [0.5f64, -0.0, 0.0];
        let x = [-2.0f64, 3.0, 5.0, -1.0];
        let alive = vec![1usize, 3];
        let plan = CompactPlan::from_alive(features, alive.clone());
        let w1c: Vec<f64> = alive
            .iter()
            .flat_map(|&f| w1[f * hidden..(f + 1) * hidden].to_vec())
            .collect();
        let mut dense = vec![0.0; hidden];
        let mut comp = vec![0.0; hidden];
        encode_dense_into(&x, &w1, &b1, hidden, &mut dense);
        encode_compact_into(&x, &w1c, &b1, hidden, &plan, &mut comp);
        assert_bits_eq(&dense, &comp, "compact vs dense with -0.0 rows");
    }

    #[test]
    fn batch_forms_match_per_sample_calls() {
        let (features, hidden, batch) = (12usize, 6usize, 5usize);
        let alive = vec![0usize, 2, 7, 9];
        let w1 = masked_weights(features, hidden, &alive, 7);
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let b1: Vec<f64> = (0..hidden).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Matrix::<f64>::randn(features, batch, &mut rng);
        let plan = CompactPlan::from_alive(features, alive.clone());
        let w1c: Vec<f64> = alive
            .iter()
            .flat_map(|&f| w1[f * hidden..(f + 1) * hidden].to_vec())
            .collect();
        let mut dense = Matrix::zeros(0, 0);
        let mut comp = Matrix::zeros(0, 0);
        encode_batch_dense_into(&x, &w1, &b1, hidden, &mut dense);
        encode_batch_compact_into(&x, &w1c, &b1, hidden, &plan, &mut comp);
        assert_eq!((dense.rows(), dense.cols()), (hidden, batch));
        assert_bits_eq(dense.as_slice(), comp.as_slice(), "batch compact vs dense");
        for j in 0..batch {
            let mut one = vec![0.0; hidden];
            encode_dense_into(x.col(j), &w1, &b1, hidden, &mut one);
            assert_bits_eq(dense.col(j), &one, "batch vs per-sample");
        }
    }

    #[test]
    fn zero_hidden_and_empty_support_are_safe() {
        // hidden = 0: nothing to write.
        let mut out: Vec<f64> = Vec::new();
        encode_dense_into(&[1.0, 2.0], &[], &[], 0, &mut out);
        // 100% sparsity: output is exactly the bias.
        let plan = CompactPlan::from_mask(&[0.0, 0.0]);
        let b1 = [0.25f64, -1.0];
        let mut out = vec![9.0f64; 2];
        encode_compact_into(&[1.0, 2.0], &[], &b1, 2, &plan, &mut out);
        assert_eq!(out, b1);
    }
}
