//! JSON wire codecs for the HTTP front-end — no serde.
//!
//! A small recursive-descent JSON value type ([`Json`]) plus the typed
//! encode/decode functions for the serve wire protocol. Matrix payloads
//! travel as a flat **column-major** `data` array (the native layout of
//! [`crate::tensor::Matrix`]) alongside `dtype`/`rows`/`cols`.
//!
//! Bit-identity over the wire: `f64` values are written with Rust's `{}`
//! formatting, which produces the shortest decimal that parses back to the
//! same bits; `f32` values are formatted from the typed slice (shortest
//! `f32` repr) and decoded by parsing to `f64` then casting — exact,
//! because every shortest-`f32` decimal is representable in `f64` and the
//! double rounding through 53 bits cannot move a 24-bit value. The
//! `net_integration` suite pins the guarantee end-to-end against
//! `Engine::submit_wait`.

use std::fmt::{self, Write as _};

use crate::projection::l1::L1Algorithm;
use crate::projection::ProjectionKind;
use crate::serve::engine::ModelInfo;
use crate::serve::{
    Dtype, EngineStats, HealthReport, HealthState, JobKind, Payload, ProjectionRequest,
    ProjectionResponse,
};
use crate::tensor::Matrix;

/// Maximum nesting depth accepted by the parser (malice guard; the wire
/// protocol itself nests three levels).
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Objects keep insertion order (`Vec`, not a map):
/// the wire shapes are small and fixed, and order-preserving output keeps
/// responses byte-stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON document (trailing whitespace only).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing bytes at offset {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Non-negative integer (rejects fractional parts and values beyond
    /// exact f64 integer range).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9_007_199_254_740_992.0 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    write!(f, "{v}")
                } else {
                    f.write_str("null")
                }
            }
            Json::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                push_json_string(&mut out, s);
                f.write_str(&out)
            }
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(fields) => {
                f.write_str("{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    let mut key = String::with_capacity(k.len() + 2);
                    push_json_string(&mut key, k);
                    write!(f, "{key}:{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Append `s` as a quoted, escaped JSON string.
fn push_json_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at offset {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at offset {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    fields.push((key, self.value(depth + 1)?));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(format!("expected ',' or '}}' at offset {}", self.pos)),
                    }
                }
            }
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected byte {:?} at offset {}", b as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("invalid number at offset {start}"))?;
        let v: f64 =
            text.parse().map_err(|_| format!("invalid number {text:?} at offset {start}"))?;
        if v.is_finite() {
            Ok(Json::Num(v))
        } else {
            Err(format!("non-finite number {text:?} at offset {start}"))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // fast path: raw UTF-8 run up to the next quote/escape
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| format!("invalid UTF-8 in string at offset {start}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: require \uXXXX low half
                                if self.peek() != Some(b'\\') {
                                    return Err("lone high surrogate".into());
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err("lone high surrogate".into());
                                }
                                self.pos += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                let code =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(code).ok_or("invalid surrogate pair")?
                            } else {
                                char::from_u32(hi).ok_or("invalid \\u escape")?
                            };
                            out.push(c);
                        }
                        _ => return Err(format!("invalid escape at offset {}", self.pos - 1)),
                    }
                }
                Some(b) => {
                    return Err(format!(
                        "unescaped control byte {b:#04x} at offset {}",
                        self.pos
                    ))
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        let hex = self
            .bytes
            .get(self.pos..end)
            .and_then(|b| std::str::from_utf8(b).ok())
            .ok_or("truncated \\u escape")?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| format!("bad \\u escape {hex:?}"))?;
        self.pos = end;
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Typed wire codecs
// ---------------------------------------------------------------------------

/// Append `v` as a JSON number (shortest round-trip repr); non-finite
/// values become `null`, which the decoders reject — the projections never
/// produce them from finite inputs, so this only surfaces corruption.
fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

fn push_f32(out: &mut String, v: f32) {
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

/// Append the `"dtype":…,"rows":…,"cols":…,"data":[…]` fields of `p`
/// (no surrounding braces, no trailing comma).
fn push_payload_fields(out: &mut String, p: &Payload) {
    let _ = write!(out, "\"dtype\":\"{}\",\"rows\":{},\"cols\":{},\"data\":[", p.dtype().name(), p.rows(), p.cols());
    match p {
        Payload::F64(m) => {
            for (i, &v) in m.as_slice().iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                push_f64(out, v);
            }
        }
        Payload::F32(m) => {
            for (i, &v) in m.as_slice().iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                push_f32(out, v);
            }
        }
    }
    out.push(']');
}

/// Decode a payload object (`dtype`/`rows`/`cols`/`data`, column-major).
pub fn decode_payload(v: &Json) -> Result<Payload, String> {
    let dtype = match v.get("dtype").and_then(Json::as_str) {
        Some("f64") => Dtype::F64,
        Some("f32") => Dtype::F32,
        Some(other) => return Err(format!("unknown dtype {other:?}")),
        None => return Err("missing dtype".into()),
    };
    let rows = v.get("rows").and_then(Json::as_usize).ok_or("missing/invalid rows")?;
    let cols = v.get("cols").and_then(Json::as_usize).ok_or("missing/invalid cols")?;
    let expect = rows.checked_mul(cols).ok_or("rows*cols overflows")?;
    let data = v.get("data").and_then(Json::as_arr).ok_or("missing data array")?;
    if data.len() != expect {
        return Err(format!("data has {} elements, expected rows*cols = {expect}", data.len()));
    }
    if expect == 0 {
        return Err("empty matrix payload".into());
    }
    match dtype {
        Dtype::F64 => {
            let mut flat = Vec::with_capacity(expect);
            for (i, item) in data.iter().enumerate() {
                flat.push(item.as_f64().ok_or_else(|| format!("data[{i}] is not a number"))?);
            }
            Ok(Payload::F64(Matrix::from_col_major(rows, cols, flat)))
        }
        Dtype::F32 => {
            let mut flat = Vec::with_capacity(expect);
            for (i, item) in data.iter().enumerate() {
                let v = item.as_f64().ok_or_else(|| format!("data[{i}] is not a number"))?;
                flat.push(v as f32);
            }
            Ok(Payload::F32(Matrix::from_col_major(rows, cols, flat)))
        }
    }
}

/// Client-side body for `POST /v1/project`.
pub fn project_request_body(req: &ProjectionRequest) -> String {
    let mut out = String::with_capacity(64 + req.payload.len() * 12);
    let _ = write!(
        out,
        "{{\"kind\":\"{}\",\"algo\":\"{}\",\"eta\":",
        req.kind.name(),
        req.algo.name()
    );
    push_f64(&mut out, req.eta);
    out.push(',');
    push_payload_fields(&mut out, &req.payload);
    out.push('}');
    out
}

/// Server-side decode for `POST /v1/project`.
pub fn decode_project_request(body: &str) -> Result<ProjectionRequest, String> {
    let v = Json::parse(body)?;
    let kind_name = v.get("kind").and_then(Json::as_str).ok_or("missing kind")?;
    let kind = ProjectionKind::parse(kind_name)
        .ok_or_else(|| format!("unknown projection kind {kind_name:?}"))?;
    let algo = match v.get("algo") {
        Some(a) => {
            let name = a.as_str().ok_or("algo must be a string")?;
            L1Algorithm::parse(name).ok_or_else(|| format!("unknown l1 algorithm {name:?}"))?
        }
        None => L1Algorithm::Condat,
    };
    let eta = v.get("eta").and_then(Json::as_f64).ok_or("missing/invalid eta")?;
    let payload = decode_payload(&v)?;
    Ok(ProjectionRequest { kind, algo, eta, payload })
}

/// Client-side body for `POST /v1/encode/{model}` (payload fields only —
/// the model id travels in the path).
pub fn encode_request_body(payload: &Payload) -> String {
    let mut out = String::with_capacity(48 + payload.len() * 12);
    out.push('{');
    push_payload_fields(&mut out, payload);
    out.push('}');
    out
}

/// Server-side decode for `POST /v1/encode/{model}`.
pub fn decode_encode_request(body: &str) -> Result<Payload, String> {
    decode_payload(&Json::parse(body)?)
}

/// Server-side body for a completed job (projection or encode).
pub fn response_body(resp: &ProjectionResponse) -> String {
    let mut out = String::with_capacity(128 + resp.payload.len() * 12);
    let _ = write!(out, "{{\"kind\":\"{}\",", resp.kind.name());
    if let JobKind::SparseEncode { model } = resp.kind {
        let _ = write!(out, "\"model\":{model},");
    }
    push_payload_fields(&mut out, &resp.payload);
    out.push_str(",\"thresholds\":");
    match &resp.thresholds {
        Some(t) => {
            out.push('[');
            for (i, &v) in t.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                push_f64(&mut out, v);
            }
            out.push(']');
        }
        None => out.push_str("null"),
    }
    let _ = write!(
        out,
        ",\"cache_hit\":{},\"batch_size\":{},\"shard\":{},\"queue_micros\":{},\"exec_micros\":{}}}",
        resp.cache_hit, resp.batch_size, resp.shard, resp.queue_micros, resp.exec_micros
    );
    out
}

/// Client-side decode of a completed-job body.
pub fn decode_response(body: &str) -> Result<ProjectionResponse, String> {
    let v = Json::parse(body)?;
    let kind_name = v.get("kind").and_then(Json::as_str).ok_or("missing kind")?;
    let kind = if kind_name == "sparse-encode" {
        let model = v.get("model").and_then(Json::as_u64).ok_or("missing model id")?;
        JobKind::SparseEncode { model }
    } else {
        JobKind::Project(
            ProjectionKind::parse(kind_name)
                .ok_or_else(|| format!("unknown response kind {kind_name:?}"))?,
        )
    };
    let payload = decode_payload(&v)?;
    let thresholds = match v.get("thresholds") {
        None | Some(Json::Null) => None,
        Some(Json::Arr(items)) => {
            let mut t = Vec::with_capacity(items.len());
            for (i, item) in items.iter().enumerate() {
                t.push(item.as_f64().ok_or_else(|| format!("thresholds[{i}] not a number"))?);
            }
            Some(t)
        }
        Some(_) => return Err("thresholds must be an array or null".into()),
    };
    Ok(ProjectionResponse {
        kind,
        payload,
        thresholds,
        cache_hit: v.get("cache_hit").and_then(Json::as_bool).unwrap_or(false),
        batch_size: v.get("batch_size").and_then(Json::as_usize).unwrap_or(1),
        shard: v.get("shard").and_then(Json::as_usize).unwrap_or(0),
        queue_micros: v.get("queue_micros").and_then(Json::as_u64).unwrap_or(0),
        exec_micros: v.get("exec_micros").and_then(Json::as_u64).unwrap_or(0),
    })
}

/// Body for `GET /v1/stats` and each SSE `stats` event.
pub fn stats_body(stats: &EngineStats) -> String {
    let mut out = String::with_capacity(256 + stats.shards.len() * 256);
    let _ = write!(
        out,
        "{{\"uptime_micros\":{},\"submitted\":{},\"completed\":{},\"rejected\":{},\"cache_hits\":{},\"cache_misses\":{},\"hit_rate\":",
        stats.uptime.as_micros(),
        stats.submitted(),
        stats.completed(),
        stats.rejected(),
        stats.cache_hits(),
        stats.cache_misses(),
    );
    push_f64(&mut out, stats.hit_rate());
    out.push_str(",\"mean_batch\":");
    push_f64(&mut out, stats.mean_batch());
    out.push_str(",\"throughput_rps\":");
    push_f64(&mut out, stats.throughput_rps());
    let _ = write!(
        out,
        ",\"worker_panics\":{},\"worker_restarts\":{}",
        stats.worker_panics(),
        stats.worker_restarts(),
    );
    out.push_str(",\"health\":{\"state\":");
    push_json_string(&mut out, stats.health.state.name());
    out.push_str(",\"reasons\":[");
    for (i, reason) in stats.health.reasons.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_json_string(&mut out, reason);
    }
    out.push_str("]}");
    out.push_str(",\"shards\":[");
    for (i, s) in stats.shards.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"shard\":{},\"depth\":{},\"submitted\":{},\"completed\":{},\"rejected\":{},\"batches\":{},\"batched_jobs\":{},\"cache_hits\":{},\"cache_misses\":{},\"worker_panics\":{},\"worker_restarts\":{},\"mean_batch\":",
            s.shard, s.depth, s.submitted, s.completed, s.rejected, s.batches, s.batched_jobs, s.cache_hits, s.cache_misses, s.worker_panics, s.worker_restarts,
        );
        push_f64(&mut out, s.mean_batch);
        out.push_str(",\"mean_queue_micros\":");
        push_f64(&mut out, s.mean_queue_micros);
        out.push_str(",\"mean_exec_micros\":");
        push_f64(&mut out, s.mean_exec_micros);
        let _ = write!(out, ",\"max_exec_micros\":{}}}", s.max_exec_micros);
    }
    out.push_str("]}");
    out
}

/// Body for `GET /v1/models`.
pub fn models_body(models: &[ModelInfo]) -> String {
    let mut out = String::from("{\"models\":[");
    for (i, m) in models.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"id\":{},\"dtype\":\"{}\",\"features\":{},\"hidden\":{},\"alive\":{}}}",
            m.id,
            m.dtype.name(),
            m.features,
            m.hidden,
            m.alive
        );
    }
    out.push_str("]}");
    out
}

/// Body for `GET /healthz`: liveness (`status`) plus the engine's
/// three-state health machine. `status` stays `"ok"` while degraded —
/// the process is alive and serving — and the `health`/`reasons` fields
/// say what is impaired.
pub fn health_body(health: &HealthReport) -> String {
    let mut out = String::from("{\"status\":");
    push_json_string(
        &mut out,
        match health.state {
            HealthState::Healthy => "ok",
            other => other.name(),
        },
    );
    out.push_str(",\"health\":");
    push_json_string(&mut out, health.state.name());
    out.push_str(",\"reasons\":[");
    for (i, reason) in health.reasons.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_json_string(&mut out, reason);
    }
    out.push_str("]}");
    out
}

/// Error body: machine-readable `error` tag + human message; 429 bodies
/// also carry the exact backoff in `retry_after_micros`.
pub fn error_body(error: &str, message: &str, retry_after_micros: Option<u64>) -> String {
    let mut out = String::with_capacity(64 + message.len());
    out.push_str("{\"error\":");
    push_json_string(&mut out, error);
    out.push_str(",\"message\":");
    push_json_string(&mut out, message);
    if let Some(micros) = retry_after_micros {
        let _ = write!(out, ",\"retry_after_micros\":{micros}");
    }
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::serve::{HealthReport, ShardStats};
    use std::time::Duration;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert!(Json::parse("\"\\ud83d\"").is_err(), "lone high surrogate");
        assert!(Json::parse("\"\\udc00\\udc00\"").is_err(), "lone low surrogate");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"unterminated", "{\"a\" 1}", "nan",
            "[1,]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_pathological_nesting() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(20) + &"]".repeat(20);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn display_round_trips_strings_and_numbers() {
        let v = Json::Obj(vec![
            ("k\"ey".into(), Json::Str("line\nbreak\t\\".into())),
            ("n".into(), Json::Num(0.1)),
            ("z".into(), Json::Arr(vec![Json::Bool(false), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn f64_payload_round_trip_is_bit_identical() {
        let tricky = vec![
            0.1,
            -0.0,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
            1.7976931348623157e308,
            -123456.789e-30,
            2.0_f64.powi(-60) + 1.0,
        ];
        let p = Payload::F64(Matrix::from_col_major(4, 2, tricky.clone()));
        let body = encode_request_body(&p);
        let back = decode_encode_request(&body).unwrap();
        let Payload::F64(m) = &back else { panic!("dtype changed") };
        for (a, b) in tricky.iter().zip(m.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} round-tripped to {b}");
        }
    }

    #[test]
    fn f32_payload_round_trip_is_bit_identical() {
        let tricky: Vec<f32> = vec![
            0.1,
            -0.0,
            1.0 / 3.0,
            f32::MIN_POSITIVE,
            1e-45, // smallest f32 subnormal
            3.4028235e38,
            -2.7182817,
            1.0000001,
        ];
        let p = Payload::F32(Matrix::from_col_major(2, 4, tricky.clone()));
        let body = encode_request_body(&p);
        let back = decode_encode_request(&body).unwrap();
        let Payload::F32(m) = &back else { panic!("dtype changed") };
        for (a, b) in tricky.iter().zip(m.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} round-tripped to {b}");
        }
    }

    #[test]
    fn project_request_round_trips() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let y = Matrix::<f64>::randn(6, 3, &mut rng);
        let req = ProjectionRequest::f64(ProjectionKind::BilevelL12, 0.75, y.clone())
            .with_algo(L1Algorithm::Michelot);
        let back = decode_project_request(&project_request_body(&req)).unwrap();
        assert_eq!(back.kind, ProjectionKind::BilevelL12);
        assert_eq!(back.algo, L1Algorithm::Michelot);
        assert_eq!(back.eta, 0.75);
        assert_eq!(back.payload.as_f64().unwrap().max_abs_diff(&y), 0.0);
        // algo defaults to condat when omitted
        let body = r#"{"kind":"bilevel-l1inf","eta":1,"dtype":"f64","rows":1,"cols":1,"data":[2]}"#;
        assert_eq!(decode_project_request(body).unwrap().algo, L1Algorithm::Condat);
    }

    #[test]
    fn decode_rejects_bad_wire_payloads() {
        for (body, why) in [
            (r#"{"kind":"bogus","eta":1,"dtype":"f64","rows":1,"cols":1,"data":[1]}"#, "kind"),
            (r#"{"kind":"bilevel-l1inf","dtype":"f64","rows":1,"cols":1,"data":[1]}"#, "eta"),
            (r#"{"kind":"bilevel-l1inf","eta":1,"dtype":"f16","rows":1,"cols":1,"data":[1]}"#, "dtype"),
            (r#"{"kind":"bilevel-l1inf","eta":1,"dtype":"f64","rows":2,"cols":1,"data":[1]}"#, "shape"),
            (r#"{"kind":"bilevel-l1inf","eta":1,"dtype":"f64","rows":0,"cols":0,"data":[]}"#, "empty"),
            (r#"{"kind":"bilevel-l1inf","eta":1,"dtype":"f64","rows":1,"cols":1,"data":[null]}"#, "null elem"),
            (r#"{"kind":"bilevel-l1inf","eta":1,"dtype":"f64","rows":1.5,"cols":1,"data":[1]}"#, "frac rows"),
        ] {
            assert!(decode_project_request(body).is_err(), "accepted bad body ({why})");
        }
    }

    #[test]
    fn response_round_trips_with_and_without_thresholds() {
        let resp = ProjectionResponse {
            kind: JobKind::Project(ProjectionKind::BilevelL1Inf),
            payload: Payload::F64(Matrix::from_col_major(2, 1, vec![1.5, -2.25])),
            thresholds: Some(vec![0.5]),
            cache_hit: true,
            batch_size: 3,
            shard: 1,
            queue_micros: 42,
            exec_micros: 17,
        };
        let back = decode_response(&response_body(&resp)).unwrap();
        assert_eq!(back.kind, resp.kind);
        assert_eq!(back.thresholds, resp.thresholds);
        assert!(back.cache_hit);
        assert_eq!((back.batch_size, back.shard), (3, 1));
        assert_eq!((back.queue_micros, back.exec_micros), (42, 17));

        let enc = ProjectionResponse {
            kind: JobKind::SparseEncode { model: 7 },
            payload: Payload::F32(Matrix::from_col_major(1, 2, vec![0.25f32, -4.0])),
            thresholds: None,
            cache_hit: false,
            batch_size: 1,
            shard: 0,
            queue_micros: 0,
            exec_micros: 1,
        };
        let back = decode_response(&response_body(&enc)).unwrap();
        assert_eq!(back.kind, JobKind::SparseEncode { model: 7 });
        assert!(back.thresholds.is_none());
        assert_eq!(back.payload.dtype(), Dtype::F32);
    }

    #[test]
    fn stats_and_models_bodies_parse() {
        let stats = EngineStats {
            uptime: Duration::from_micros(1234),
            shards: vec![ShardStats {
                shard: 0,
                depth: 2,
                submitted: 10,
                completed: 9,
                rejected: 1,
                batches: 4,
                batched_jobs: 9,
                cache_hits: 3,
                cache_misses: 2,
                worker_panics: 1,
                worker_restarts: 1,
                mean_batch: 2.25,
                hit_rate: 0.6,
                mean_queue_micros: 11.5,
                mean_exec_micros: 99.0,
                max_exec_micros: 200,
            }],
            health: HealthReport::degraded(vec!["model 7 circuit open".into()]),
        };
        let v = Json::parse(&stats_body(&stats)).unwrap();
        assert_eq!(v.get("completed").unwrap().as_u64(), Some(9));
        assert_eq!(v.get("uptime_micros").unwrap().as_u64(), Some(1234));
        assert_eq!(v.get("worker_panics").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("worker_restarts").unwrap().as_u64(), Some(1));
        let health = v.get("health").unwrap();
        assert_eq!(health.get("state").unwrap().as_str(), Some("degraded"));
        assert_eq!(
            health.get("reasons").unwrap().as_arr().unwrap()[0].as_str(),
            Some("model 7 circuit open")
        );
        let shards = v.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards[0].get("depth").unwrap().as_u64(), Some(2));
        assert_eq!(shards[0].get("max_exec_micros").unwrap().as_u64(), Some(200));
        assert_eq!(shards[0].get("worker_panics").unwrap().as_u64(), Some(1));

        let models = vec![ModelInfo { id: 3, dtype: Dtype::F32, features: 10, hidden: 4, alive: 7 }];
        let v = Json::parse(&models_body(&models)).unwrap();
        let arr = v.get("models").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("id").unwrap().as_u64(), Some(3));
        assert_eq!(arr[0].get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(arr[0].get("alive").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn error_body_shape() {
        let v = Json::parse(&error_body("overloaded", "shard 0 full", Some(250))).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("retry_after_micros").unwrap().as_u64(), Some(250));
        let v = Json::parse(&error_body("bad_request", "nope \"quoted\"", None)).unwrap();
        assert!(v.get("retry_after_micros").is_none());
        assert_eq!(v.get("message").unwrap().as_str(), Some("nope \"quoted\""));
    }
}
