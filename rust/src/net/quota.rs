//! Per-client token-bucket admission.
//!
//! [`QuotaGate`] sits in front of the engine's queue-depth backpressure:
//! the engine protects itself (reject + retry-after when a shard queue is
//! at its high-water mark), the quota protects *other clients* from one
//! chatty one. Buckets are keyed by the `X-Client-Id` header when the
//! client sends one, else the remote IP; each holds `burst` tokens and
//! refills at `rate` tokens/second. An empty bucket rejects with the exact
//! wait until one token accrues — the routes layer turns that into `429`
//! with `Retry-After`, tagged `quota` so clients (and the integration
//! tests) can tell it apart from queue overload (`overloaded`).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::sync::lock_unpoisoned;

/// Evict idle buckets once the map outgrows this (bounds memory against
/// client-id churn/spoofing).
const MAX_TRACKED: usize = 4096;
const STALE_AFTER: Duration = Duration::from_secs(60);

struct Bucket {
    /// Fractional tokens available.
    tokens: f64,
    last: Instant,
}

/// Token-bucket rate limiter over client keys.
pub struct QuotaGate {
    /// Sustained tokens (requests) per second per client.
    rate: f64,
    /// Bucket capacity (burst allowance).
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl QuotaGate {
    /// `rate` requests/second sustained, bursts up to `burst`. Both must
    /// be positive (an unlimited gate is represented by not building one).
    pub fn new(rate: f64, burst: f64) -> Self {
        assert!(rate > 0.0 && burst > 0.0, "quota rate/burst must be positive");
        Self { rate, burst, buckets: Mutex::new(HashMap::new()) }
    }

    /// Take one token for `key`. `Err(wait)` is the time until a token
    /// accrues (never zero).
    pub fn admit(&self, key: &str) -> Result<(), Duration> {
        let now = Instant::now();
        let mut buckets = lock_unpoisoned(&self.buckets);
        if buckets.len() >= MAX_TRACKED && !buckets.contains_key(key) {
            buckets.retain(|_, b| now.duration_since(b.last) < STALE_AFTER);
            // A spoofed-`X-Client-Id` flood keeps every bucket fresh, so
            // the stale sweep alone can evict nothing and the insert below
            // would grow the map without bound. Hard cap: drop the
            // least-recently-used bucket to make room. (The victim loses
            // only its refill progress — at most one request's worth of
            // fairness — while the map stays bounded.)
            if buckets.len() >= MAX_TRACKED {
                let oldest: Option<String> =
                    buckets.iter().min_by_key(|(_, b)| b.last).map(|(k, _)| k.clone());
                if let Some(k) = oldest {
                    buckets.remove(&k);
                }
            }
        }
        let bucket = buckets
            .entry(key.to_string())
            .or_insert(Bucket { tokens: self.burst, last: now });
        let elapsed = now.duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + elapsed * self.rate).min(self.burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Ok(())
        } else {
            let deficit = 1.0 - bucket.tokens;
            Err(Duration::from_secs_f64(deficit / self.rate).max(Duration::from_micros(1)))
        }
    }

    /// Clients currently tracked (tests / stats).
    pub fn tracked(&self) -> usize {
        lock_unpoisoned(&self.buckets).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_reject_with_positive_backoff() {
        // 0.1 tokens/sec: nothing refills within the test's lifetime.
        let gate = QuotaGate::new(0.1, 2.0);
        assert!(gate.admit("a").is_ok());
        assert!(gate.admit("a").is_ok());
        let wait = gate.admit("a").unwrap_err();
        assert!(wait > Duration::ZERO);
        assert!(wait <= Duration::from_secs(10), "wait bounded by 1/rate: {wait:?}");
    }

    #[test]
    fn clients_have_independent_buckets() {
        let gate = QuotaGate::new(0.1, 1.0);
        assert!(gate.admit("a").is_ok());
        assert!(gate.admit("a").is_err());
        assert!(gate.admit("b").is_ok(), "b must not be throttled by a");
        assert_eq!(gate.tracked(), 2);
    }

    #[test]
    fn refill_restores_admission() {
        // 1000 tokens/sec: a few ms restores a token.
        let gate = QuotaGate::new(1000.0, 1.0);
        assert!(gate.admit("a").is_ok());
        let wait = gate.admit("a").unwrap_err();
        std::thread::sleep(wait + Duration::from_millis(2));
        assert!(gate.admit("a").is_ok(), "token must refill after the advertised wait");
    }

    #[test]
    fn tokens_cap_at_burst() {
        // slow refill so elapsed time between admits is negligible
        let gate = QuotaGate::new(0.01, 2.0);
        assert!(gate.admit("a").is_ok());
        // long idle must not accumulate beyond `burst`
        std::thread::sleep(Duration::from_millis(5));
        assert!(gate.admit("a").is_ok());
        assert!(gate.admit("a").is_err(), "burst cap exceeded");
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_rate_is_a_bug() {
        let _ = QuotaGate::new(0.0, 1.0);
    }

    #[test]
    fn poisoned_lock_still_admits() {
        // Regression for the `lock_unpoisoned` migration: a panic while
        // holding the buckets lock (here forced directly; in production a
        // panicking request thread) must not wedge admission — pre-fix
        // every later `admit` panicked on the poisoned mutex and the serve
        // path answered nothing.
        let gate = QuotaGate::new(1000.0, 2.0);
        assert!(gate.admit("a").is_ok());
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = gate.buckets.lock().unwrap();
            panic!("poison the buckets lock");
        }));
        assert!(unwound.is_err());
        assert!(gate.buckets.lock().is_err(), "lock must actually be poisoned");
        assert!(gate.admit("a").is_ok(), "admit must answer on a poisoned lock");
        assert_eq!(gate.tracked(), 1, "bucket state must survive the poisoning");
    }

    #[test]
    fn tracked_never_exceeds_cap_under_id_flood() {
        // Regression: all buckets stay fresh (created microseconds ago, so
        // the STALE_AFTER sweep evicts nothing) while a spoofed client id
        // changes every request. Pre-fix the map grew past MAX_TRACKED.
        let gate = QuotaGate::new(1000.0, 4.0);
        for i in 0..(MAX_TRACKED + 500) {
            let _ = gate.admit(&format!("client-{i}"));
            assert!(
                gate.tracked() <= MAX_TRACKED,
                "tracked {} exceeded cap at request {i}",
                gate.tracked()
            );
        }
        assert_eq!(gate.tracked(), MAX_TRACKED);
    }
}
