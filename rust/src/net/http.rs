//! HTTP/1.1 message primitives over plain `std::io` streams.
//!
//! Dependency-free request parsing and response writing for the serve
//! front-end, plus the client half the network loadgen and integration
//! tests drive. Hardening is part of the contract, not an afterthought:
//!
//! * header section capped at [`HttpLimits::max_header_bytes`] → `431`
//! * bodies (Content-Length **and** decoded chunked) capped at
//!   [`HttpLimits::max_body_bytes`] → `413`
//! * slow/stalled peers surface as [`HttpError::TimedOut`] (the server
//!   sets `set_read_timeout` on the socket) → `408`
//! * anything structurally wrong is [`HttpError::Malformed`] → `400`
//!
//! Keep-alive is the default for HTTP/1.1 peers; `Connection: close` (or
//! an HTTP/1.0 request) closes after the response. Chunked
//! transfer-encoding is supported both ways — the SSE stats stream writes
//! chunks via [`write_response_head`] / [`write_chunk`].

use std::fmt;
use std::io::{self, BufRead, Read, Write};

/// Parse-time resource limits (wired from `[serve.http]`).
#[derive(Clone, Copy, Debug)]
pub struct HttpLimits {
    pub max_header_bytes: usize,
    pub max_body_bytes: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        Self { max_header_bytes: 16 * 1024, max_body_bytes: 16 * 1024 * 1024 }
    }
}

/// Why reading an HTTP message failed. Each variant maps to one status
/// code in the connection loop (see module docs).
#[derive(Debug)]
pub enum HttpError {
    /// Structurally invalid message (bad request line, header, chunk…).
    Malformed(String),
    /// Header section exceeded `max_header_bytes`.
    HeadersTooLarge,
    /// Declared or decoded body exceeded `max_body_bytes`.
    BodyTooLarge,
    /// Peer closed the connection mid-message.
    UnexpectedEof,
    /// Read timeout expired (slow or stalled peer).
    TimedOut,
    /// Any other transport error.
    Io(io::Error),
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Malformed(msg) => write!(f, "malformed message: {msg}"),
            Self::HeadersTooLarge => write!(f, "header section too large"),
            Self::BodyTooLarge => write!(f, "body too large"),
            Self::UnexpectedEof => write!(f, "connection closed mid-message"),
            Self::TimedOut => write!(f, "read timed out"),
            Self::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<io::Error> for HttpError {
    fn from(e: io::Error) -> Self {
        match e.kind() {
            // Unix reports an expired SO_RCVTIMEO as WouldBlock.
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => Self::TimedOut,
            io::ErrorKind::UnexpectedEof => Self::UnexpectedEof,
            _ => Self::Io(e),
        }
    }
}

/// A parsed request. Header names are lowercased at parse time; values
/// keep their bytes (trimmed).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string (no leading `?`), empty if absent.
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// False for HTTP/1.0 peers (implies `Connection: close` semantics).
    pub http11: bool,
}

impl Request {
    /// First header value by (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Value of `name` in the query string (`a=1&b=2` form; no decoding).
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (k == name).then_some(v)
        })
    }

    /// Whether the client asked to keep the connection open.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// A parsed response (client side). Chunked bodies arrive already
/// de-chunked; use [`read_response_head`] + [`read_chunk`] instead to
/// stream (SSE).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Standard reason phrase for the status codes the front-end emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// Read one CRLF- (or LF-) terminated line, excluding the terminator.
/// `budget` is decremented by the bytes consumed. `Ok(None)` only at
/// clean EOF before the first byte.
fn read_line<R: BufRead>(
    r: &mut R,
    budget: &mut usize,
) -> Result<Option<String>, HttpError> {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(HttpError::UnexpectedEof);
            }
            Ok(_) => {
                if *budget == 0 {
                    return Err(HttpError::HeadersTooLarge);
                }
                *budget -= 1;
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return Ok(Some(String::from_utf8(line).map_err(|_| {
                        HttpError::Malformed("non-UTF-8 header line".into())
                    })?));
                }
                line.push(byte[0]);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
}

/// Read the header block (after the start line) into lowercased pairs.
fn read_headers<R: BufRead>(
    r: &mut R,
    budget: &mut usize,
) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r, budget)?.ok_or(HttpError::UnexpectedEof)?;
        if line.is_empty() {
            return Ok(headers);
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("header without colon: {line:?}")))?;
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::Malformed(format!("invalid header name: {name:?}")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
}

/// Read a message body given the parsed headers.
fn read_body<R: BufRead>(
    r: &mut R,
    headers: &[(String, String)],
    limits: &HttpLimits,
) -> Result<Vec<u8>, HttpError> {
    let find = |name: &str| headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str());
    if let Some(te) = find("transfer-encoding") {
        if !te.eq_ignore_ascii_case("chunked") {
            return Err(HttpError::Malformed(format!("unsupported transfer-encoding {te:?}")));
        }
        let mut body = Vec::new();
        while let Some(chunk) = read_chunk_limited(r, limits.max_body_bytes)? {
            if body.len() + chunk.len() > limits.max_body_bytes {
                return Err(HttpError::BodyTooLarge);
            }
            body.extend_from_slice(&chunk);
        }
        return Ok(body);
    }
    match find("content-length") {
        Some(v) => {
            let n: usize = v
                .trim()
                .parse()
                .map_err(|_| HttpError::Malformed(format!("bad content-length {v:?}")))?;
            if n > limits.max_body_bytes {
                return Err(HttpError::BodyTooLarge);
            }
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)?;
            Ok(body)
        }
        None => Ok(Vec::new()),
    }
}

/// Server side: read one request. `Ok(None)` means the peer closed the
/// connection cleanly between requests (normal keep-alive end). A
/// `100-continue` expectation is acknowledged on `w` before the body is
/// read (curl sends it for large payloads and stalls without the ack).
pub fn read_request<R: BufRead, W: Write>(
    r: &mut R,
    w: &mut W,
    limits: &HttpLimits,
) -> Result<Option<Request>, HttpError> {
    let mut budget = limits.max_header_bytes;
    let Some(start) = read_line(r, &mut budget)? else {
        return Ok(None);
    };
    let mut parts = start.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(HttpError::Malformed(format!("bad request line: {start:?}"))),
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => return Err(HttpError::Malformed(format!("unsupported version {other:?}"))),
    };
    let headers = read_headers(r, &mut budget)?;
    if headers
        .iter()
        .any(|(k, v)| k == "expect" && v.eq_ignore_ascii_case("100-continue"))
    {
        w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
        w.flush()?;
    }
    let body = read_body(r, &headers, limits)?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    Ok(Some(Request { method: method.to_string(), path, query, headers, body, http11 }))
}

/// Write a complete response with `Content-Length` framing.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    extra_headers: &[(String, String)],
    keep_alive: bool,
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write a response head announcing a chunked body (streaming / SSE).
pub fn write_response_head<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(String, String)],
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    w.write_all(b"Transfer-Encoding: chunked\r\n")?;
    w.write_all(b"Cache-Control: no-store\r\n")?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"Connection: close\r\n\r\n")?;
    w.flush()
}

/// Write one transfer-encoding chunk (no-op for empty data — an empty
/// chunk would terminate the stream).
pub fn write_chunk<W: Write>(w: &mut W, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked body.
pub fn finish_chunks<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// Client side: write a request with `Content-Length` framing.
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    target: &str,
    headers: &[(String, String)],
    body: &[u8],
) -> io::Result<()> {
    write!(w, "{method} {target} HTTP/1.1\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    if !body.is_empty() || method == "POST" {
        write!(w, "Content-Length: {}\r\n", body.len())?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Client side: read a response head (status + headers), body not yet
/// consumed. Use when the body is a chunked stream to iterate with
/// [`read_chunk`].
pub fn read_response_head<R: BufRead>(
    r: &mut R,
    limits: &HttpLimits,
) -> Result<(u16, Vec<(String, String)>), HttpError> {
    let mut budget = limits.max_header_bytes;
    let start = read_line(r, &mut budget)?.ok_or(HttpError::UnexpectedEof)?;
    let mut parts = start.split(' ');
    let (version, code) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("bad status line: {start:?}")));
    }
    let status: u16 = code
        .parse()
        .map_err(|_| HttpError::Malformed(format!("bad status code {code:?}")))?;
    let headers = read_headers(r, &mut budget)?;
    Ok((status, headers))
}

/// Client side: read a complete response (chunked bodies de-chunked).
pub fn read_response<R: BufRead>(r: &mut R, limits: &HttpLimits) -> Result<Response, HttpError> {
    let (status, headers) = read_response_head(r, limits)?;
    let body = read_body(r, &headers, limits)?;
    Ok(Response { status, headers, body })
}

/// Read one chunk of a chunked body; `Ok(None)` at the terminating
/// 0-chunk (trailers consumed).
pub fn read_chunk<R: BufRead>(r: &mut R) -> Result<Option<Vec<u8>>, HttpError> {
    read_chunk_limited(r, usize::MAX)
}

fn read_chunk_limited<R: BufRead>(
    r: &mut R,
    max: usize,
) -> Result<Option<Vec<u8>>, HttpError> {
    // Chunk-size lines are tiny; a generous fixed budget suffices.
    let mut budget = 1024;
    let line = read_line(r, &mut budget)?.ok_or(HttpError::UnexpectedEof)?;
    let size_str = line.split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_str, 16)
        .map_err(|_| HttpError::Malformed(format!("bad chunk size {size_str:?}")))?;
    if size == 0 {
        // trailers (if any) end with an empty line
        loop {
            let mut budget = 1024;
            let t = read_line(r, &mut budget)?.ok_or(HttpError::UnexpectedEof)?;
            if t.is_empty() {
                return Ok(None);
            }
        }
    }
    if size > max {
        return Err(HttpError::BodyTooLarge);
    }
    let mut data = vec![0u8; size];
    r.read_exact(&mut data)?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    if &crlf != b"\r\n" {
        return Err(HttpError::Malformed("chunk not CRLF-terminated".into()));
    }
    Ok(Some(data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8], limits: &HttpLimits) -> Result<Option<Request>, HttpError> {
        let mut sink = Vec::new();
        read_request(&mut Cursor::new(raw), &mut sink, limits)
    }

    #[test]
    fn parses_a_basic_request() {
        let raw = b"POST /v1/project?n=3 HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\nX-Client-Id: abc\r\n\r\nhello";
        let req = parse(raw, &HttpLimits::default()).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/project");
        assert_eq!(req.query, "n=3");
        assert_eq!(req.query_param("n"), Some("3"));
        assert_eq!(req.query_param("missing"), None);
        assert_eq!(req.header("x-client-id"), Some("abc"));
        assert_eq!(req.header("X-CLIENT-ID"), Some("abc"));
        assert_eq!(req.body, b"hello");
        assert!(req.http11);
        assert!(req.keep_alive());
    }

    #[test]
    fn connection_close_and_http10() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = parse(raw, &HttpLimits::default()).unwrap().unwrap();
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        let req = parse(raw, &HttpLimits::default()).unwrap().unwrap();
        assert!(!req.http11);
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
        assert!(parse(raw, &HttpLimits::default()).unwrap().unwrap().keep_alive());
    }

    #[test]
    fn clean_eof_is_none_mid_message_is_error() {
        assert!(parse(b"", &HttpLimits::default()).unwrap().is_none());
        assert!(matches!(
            parse(b"GET / HT", &HttpLimits::default()),
            Err(HttpError::UnexpectedEof)
        ));
        assert!(matches!(
            parse(b"GET / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc", &HttpLimits::default()),
            Err(HttpError::UnexpectedEof)
        ));
    }

    #[test]
    fn malformed_requests_are_rejected() {
        for raw in [
            &b"NOT-A-REQUEST\r\n\r\n"[..],
            b"GET / SPDY/3\r\n\r\n",
            b"GET / HTTP/1.1 extra\r\n\r\n",
            b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n",
            b"GET / HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
            b"GET / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n",
        ] {
            assert!(
                matches!(parse(raw, &HttpLimits::default()), Err(HttpError::Malformed(_))),
                "accepted {:?}",
                String::from_utf8_lossy(raw)
            );
        }
    }

    #[test]
    fn oversized_headers_and_bodies_are_rejected() {
        let limits = HttpLimits { max_header_bytes: 64, max_body_bytes: 8 };
        let raw = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(100));
        assert!(matches!(parse(raw.as_bytes(), &limits), Err(HttpError::HeadersTooLarge)));
        // declared oversized body is rejected without reading it
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\n123456789";
        assert!(matches!(parse(raw, &limits), Err(HttpError::BodyTooLarge)));
        // chunked body that decodes past the cap is rejected too
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n6\r\nabcdef\r\n6\r\nghijkl\r\n0\r\n\r\n";
        assert!(matches!(parse(raw, &limits), Err(HttpError::BodyTooLarge)));
    }

    #[test]
    fn chunked_request_body_is_decoded() {
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n6\r\n world\r\n0\r\n\r\n";
        let req = parse(raw, &HttpLimits::default()).unwrap().unwrap();
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn expect_100_continue_is_acknowledged() {
        let raw = b"POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nok";
        let mut ack = Vec::new();
        let req = read_request(&mut Cursor::new(&raw[..]), &mut ack, &HttpLimits::default())
            .unwrap()
            .unwrap();
        assert_eq!(req.body, b"ok");
        assert!(ack.starts_with(b"HTTP/1.1 100 Continue"));
    }

    #[test]
    fn response_round_trip() {
        let mut buf = Vec::new();
        write_response(
            &mut buf,
            429,
            "application/json",
            b"{\"error\":\"overloaded\"}",
            &[("Retry-After".into(), "1".into())],
            true,
        )
        .unwrap();
        let resp = read_response(&mut Cursor::new(&buf), &HttpLimits::default()).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.body, b"{\"error\":\"overloaded\"}");
    }

    #[test]
    fn chunked_response_streams_and_terminates() {
        let mut buf = Vec::new();
        write_response_head(&mut buf, 200, "text/event-stream", &[]).unwrap();
        write_chunk(&mut buf, b"event: stats\ndata: {}\n\n").unwrap();
        write_chunk(&mut buf, b"").unwrap(); // no-op, must not terminate
        write_chunk(&mut buf, b"second").unwrap();
        finish_chunks(&mut buf).unwrap();
        let mut r = Cursor::new(&buf);
        let (status, headers) = read_response_head(&mut r, &HttpLimits::default()).unwrap();
        assert_eq!(status, 200);
        assert!(headers.iter().any(|(k, v)| k == "transfer-encoding" && v == "chunked"));
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"event: stats\ndata: {}\n\n");
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"second");
        assert!(read_chunk(&mut r).unwrap().is_none());
        // whole-body read path de-chunks the same bytes
        let mut r = Cursor::new(&buf);
        let resp = read_response(&mut r, &HttpLimits::default()).unwrap();
        assert_eq!(resp.body, b"event: stats\ndata: {}\n\nsecond");
    }

    #[test]
    fn client_request_writer_frames_posts() {
        let mut buf = Vec::new();
        write_request(&mut buf, "POST", "/v1/project", &[("Host".into(), "x".into())], b"{}")
            .unwrap();
        let mut sink = Vec::new();
        let req = read_request(&mut Cursor::new(&buf), &mut sink, &HttpLimits::default())
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{}");
        // GET with no body carries no Content-Length
        let mut buf = Vec::new();
        write_request(&mut buf, "GET", "/healthz", &[], b"").unwrap();
        assert!(!String::from_utf8(buf).unwrap().contains("Content-Length"));
    }

    #[test]
    fn timeout_error_kind_maps() {
        let e: HttpError = io::Error::new(io::ErrorKind::WouldBlock, "t").into();
        assert!(matches!(e, HttpError::TimedOut));
        let e: HttpError = io::Error::new(io::ErrorKind::TimedOut, "t").into();
        assert!(matches!(e, HttpError::TimedOut));
        let e: HttpError = io::Error::new(io::ErrorKind::BrokenPipe, "t").into();
        assert!(matches!(e, HttpError::Io(_)));
    }
}
