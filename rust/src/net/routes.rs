//! Route table: HTTP requests → engine calls.
//!
//! [`dispatch`] is pure request→action logic (no sockets), so every route
//! is unit-testable without a listener:
//!
//! | route | maps to |
//! |---|---|
//! | `POST /v1/project` | [`Engine::submit_wait`] |
//! | `POST /v1/encode/{model}` | [`Engine::submit_encode_wait`] |
//! | `GET /v1/stats` | [`Engine::stats`] as JSON |
//! | `GET /v1/models` | [`Engine::models`] as JSON |
//! | `GET /v1/events` | SSE stream of stats snapshots ([`stream_stats`]) |
//! | `GET /healthz` | liveness (503 while draining) |
//! | `POST /v1/drain` | begin graceful drain (idempotent) |
//!
//! Failures are typed ([`RouteError`]) and carry their HTTP status, a
//! machine-readable tag, and — for the two 429 sources — the backoff.
//! **Quota exhaustion and queue overload are deliberately distinct tags**
//! (`quota` vs `overloaded`): both are 429 + `Retry-After`, but one means
//! "you specifically are over your budget" and the other "the service is
//! saturated"; clients back off differently and the integration tests
//! assert the tags.

use std::io::{self, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::serve::{Engine, SubmitError};

use super::http::{finish_chunks, write_chunk, write_response_head, Request};
use super::quota::QuotaGate;
use super::wire;

/// Shared state a request is dispatched against.
pub struct RouteCtx<'a> {
    pub engine: &'a Engine,
    /// `None` disables quota admission (`quota_rps = 0`).
    pub quota: Option<&'a QuotaGate>,
    pub draining: &'a AtomicBool,
}

/// What the connection loop should do for a request.
#[derive(Debug)]
pub enum Action {
    /// Plain JSON response.
    Respond { status: u16, body: String },
    /// Stream SSE stats snapshots (`limit` = `?n=` query, None = until
    /// drain/disconnect).
    StreamStats { limit: Option<u64> },
    /// Respond with `body` and then start a graceful drain.
    BeginDrain { body: String },
}

/// A refused request, typed so the server can render status + headers +
/// JSON body uniformly.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteError {
    BadRequest(String),
    NotFound(String),
    MethodNotAllowed(String),
    /// This client exhausted its token bucket.
    QuotaExceeded { client: String, retry_after: Duration },
    /// The engine's shard queue is at its high-water mark.
    Overloaded { retry_after: Duration },
    /// The model's circuit breaker is open: recent jobs against it kept
    /// failing, so the engine refuses new ones until the cooldown lapses.
    CircuitOpen { model: u64, retry_after: Duration },
    /// The job was accepted but the worker executing it panicked; the
    /// supervisor has already respawned the worker.
    WorkerFailed(String),
    /// The server is draining (or the engine is shutting down).
    Draining,
}

impl RouteError {
    pub fn status(&self) -> u16 {
        match self {
            Self::BadRequest(_) => 400,
            Self::NotFound(_) => 404,
            Self::MethodNotAllowed(_) => 405,
            Self::QuotaExceeded { .. } | Self::Overloaded { .. } => 429,
            Self::WorkerFailed(_) => 500,
            Self::CircuitOpen { .. } | Self::Draining => 503,
        }
    }

    /// Machine-readable tag for the JSON `error` field.
    pub fn tag(&self) -> &'static str {
        match self {
            Self::BadRequest(_) => "bad_request",
            Self::NotFound(_) => "not_found",
            Self::MethodNotAllowed(_) => "method_not_allowed",
            Self::QuotaExceeded { .. } => "quota",
            Self::Overloaded { .. } => "overloaded",
            Self::CircuitOpen { .. } => "circuit_open",
            Self::WorkerFailed(_) => "worker_panic",
            Self::Draining => "draining",
        }
    }

    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            Self::QuotaExceeded { retry_after, .. }
            | Self::Overloaded { retry_after }
            | Self::CircuitOpen { retry_after, .. } => Some(*retry_after),
            _ => None,
        }
    }

    pub fn message(&self) -> String {
        match self {
            Self::BadRequest(m) | Self::NotFound(m) | Self::MethodNotAllowed(m) => m.clone(),
            Self::QuotaExceeded { client, retry_after } => {
                format!("client {client:?} over quota; retry after {retry_after:?}")
            }
            Self::Overloaded { retry_after } => {
                format!("engine overloaded; retry after {retry_after:?}")
            }
            Self::CircuitOpen { model, retry_after } => {
                format!("model {model} circuit open; retry after {retry_after:?}")
            }
            Self::WorkerFailed(m) => m.clone(),
            Self::Draining => "server is draining; no new work accepted".into(),
        }
    }

    /// Extra response headers: 429s advertise `Retry-After` in whole
    /// seconds (HTTP semantics, rounded up, min 1) plus the exact backoff
    /// in `X-Retry-After-Micros` — the network loadgen uses the latter.
    pub fn headers(&self) -> Vec<(String, String)> {
        match self.retry_after() {
            Some(d) => {
                let secs = d.as_secs() + u64::from(d.subsec_nanos() > 0);
                vec![
                    ("Retry-After".into(), secs.max(1).to_string()),
                    ("X-Retry-After-Micros".into(), (d.as_micros() as u64).to_string()),
                ]
            }
            None => Vec::new(),
        }
    }

    /// JSON body for this error.
    pub fn body(&self) -> String {
        wire::error_body(
            self.tag(),
            &self.message(),
            self.retry_after().map(|d| d.as_micros() as u64),
        )
    }
}

/// Quota key: explicit client id if the request carries one, else the
/// peer address (IP without port).
fn client_key(req: &Request, peer: &str) -> String {
    match req.header("x-client-id") {
        Some(id) if !id.is_empty() => id.to_string(),
        _ => peer.to_string(),
    }
}

fn submit_error(e: SubmitError) -> RouteError {
    match e {
        SubmitError::Invalid(msg) => RouteError::BadRequest(msg),
        SubmitError::Overloaded { retry_after, .. } => RouteError::Overloaded { retry_after },
        SubmitError::CircuitOpen { model, retry_after } => {
            RouteError::CircuitOpen { model, retry_after }
        }
        SubmitError::Failed(e) => RouteError::WorkerFailed(e.to_string()),
        SubmitError::ShuttingDown => RouteError::Draining,
    }
}

/// Admission shared by the two submit routes: quota first (cheap, per
/// client), then the drain gate.
fn admit_submit(req: &Request, peer: &str, ctx: &RouteCtx) -> Result<(), RouteError> {
    if let Some(gate) = ctx.quota {
        let client = client_key(req, peer);
        if let Err(retry_after) = gate.admit(&client) {
            return Err(RouteError::QuotaExceeded { client, retry_after });
        }
    }
    if ctx.draining.load(Ordering::SeqCst) {
        return Err(RouteError::Draining);
    }
    Ok(())
}

/// Route one parsed request. Blocking: the submit routes wait for the
/// engine response on the connection's thread (thread-per-connection).
pub fn dispatch(req: &Request, peer: &str, ctx: &RouteCtx) -> Result<Action, RouteError> {
    let method = req.method.as_str();
    let path = req.path.as_str();
    match path {
        "/healthz" => {
            if method != "GET" {
                return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
            }
            if ctx.draining.load(Ordering::SeqCst) {
                return Err(RouteError::Draining);
            }
            // Liveness stays 200 while degraded — the body carries the
            // health machine so probes can distinguish the states.
            let health = ctx.engine.stats().health;
            Ok(Action::Respond { status: 200, body: wire::health_body(&health) })
        }
        "/v1/stats" => {
            if method != "GET" {
                return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
            }
            Ok(Action::Respond { status: 200, body: wire::stats_body(&ctx.engine.stats()) })
        }
        "/v1/models" => {
            if method != "GET" {
                return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
            }
            Ok(Action::Respond { status: 200, body: wire::models_body(&ctx.engine.models()) })
        }
        "/v1/events" => {
            if method != "GET" {
                return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
            }
            let limit = match req.query_param("n") {
                Some(n) => Some(
                    n.parse::<u64>()
                        .map_err(|_| RouteError::BadRequest(format!("bad ?n= value {n:?}")))?,
                ),
                None => None,
            };
            Ok(Action::StreamStats { limit })
        }
        "/v1/drain" => {
            if method != "POST" {
                return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
            }
            Ok(Action::BeginDrain { body: "{\"status\":\"draining\"}".into() })
        }
        "/v1/project" => {
            if method != "POST" {
                return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
            }
            admit_submit(req, peer, ctx)?;
            let body = std::str::from_utf8(&req.body)
                .map_err(|_| RouteError::BadRequest("body is not UTF-8".into()))?;
            let preq = wire::decode_project_request(body).map_err(RouteError::BadRequest)?;
            let resp = ctx.engine.submit_wait(preq).map_err(submit_error)?;
            Ok(Action::Respond { status: 200, body: wire::response_body(&resp) })
        }
        _ => {
            if let Some(model) = path.strip_prefix("/v1/encode/") {
                if method != "POST" {
                    return Err(RouteError::MethodNotAllowed(format!("{method} {path}")));
                }
                let model: u64 = model
                    .parse()
                    .map_err(|_| RouteError::BadRequest(format!("bad model id {model:?}")))?;
                admit_submit(req, peer, ctx)?;
                let body = std::str::from_utf8(&req.body)
                    .map_err(|_| RouteError::BadRequest("body is not UTF-8".into()))?;
                let payload = wire::decode_encode_request(body).map_err(RouteError::BadRequest)?;
                let resp = ctx.engine.submit_encode_wait(model, payload).map_err(submit_error)?;
                Ok(Action::Respond { status: 200, body: wire::response_body(&resp) })
            } else {
                Err(RouteError::NotFound(format!("no route for {path}")))
            }
        }
    }
}

/// Stream per-shard stats snapshots as SSE until `limit` events are sent,
/// the server drains, or the client disconnects (write error). Each event
/// carries a monotonically increasing `seq`; a final `drain` event is
/// emitted when the stream ends because of a drain.
pub fn stream_stats<W: Write>(
    w: &mut W,
    engine: &Engine,
    draining: &AtomicBool,
    interval: Duration,
    limit: Option<u64>,
) -> io::Result<()> {
    write_response_head(w, 200, "text/event-stream", &[])?;
    let mut seq = 0u64;
    loop {
        if limit.is_some_and(|n| seq >= n) {
            break;
        }
        let stats = wire::stats_body(&engine.stats());
        // splice the sequence number into the stats object
        let event = format!("event: stats\ndata: {{\"seq\":{seq},{}\n\n", &stats[1..]);
        write_chunk(w, event.as_bytes())?;
        seq += 1;
        if draining.load(Ordering::SeqCst) {
            break;
        }
        // sleep in short slices so a drain ends the stream promptly
        let mut remaining = interval;
        while remaining > Duration::ZERO && !draining.load(Ordering::SeqCst) {
            let step = remaining.min(Duration::from_millis(20));
            std::thread::sleep(step);
            remaining = remaining.saturating_sub(step);
        }
    }
    if draining.load(Ordering::SeqCst) {
        write_chunk(w, b"event: drain\ndata: {\"status\":\"draining\"}\n\n")?;
    }
    finish_chunks(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::projection::ProjectionKind;
    use crate::rng::Xoshiro256pp;
    use crate::serve::ProjectionRequest;
    use crate::tensor::Matrix;

    fn get(path: &str) -> Request {
        request("GET", path, b"")
    }

    fn request(method: &str, target: &str, body: &[u8]) -> Request {
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => (target.to_string(), String::new()),
        };
        Request {
            method: method.into(),
            path,
            query,
            headers: Vec::new(),
            body: body.to_vec(),
            http11: true,
        }
    }

    fn small_engine() -> Engine {
        Engine::start(&ServeConfig {
            shards: 1,
            workers_per_shard: 1,
            cache_capacity: 8,
            ..ServeConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn health_stats_models_routes() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let ctx = RouteCtx { engine: &engine, quota: None, draining: &draining };
        let Action::Respond { status, body } = dispatch(&get("/healthz"), "ip", &ctx).unwrap()
        else {
            panic!("healthz must respond")
        };
        assert_eq!(status, 200);
        assert!(body.contains("ok"));
        assert!(matches!(
            dispatch(&get("/v1/stats"), "ip", &ctx),
            Ok(Action::Respond { status: 200, .. })
        ));
        assert!(matches!(
            dispatch(&get("/v1/models"), "ip", &ctx),
            Ok(Action::Respond { status: 200, .. })
        ));
        // draining flips healthz to 503
        draining.store(true, Ordering::SeqCst);
        let err = dispatch(&get("/healthz"), "ip", &ctx).unwrap_err();
        assert_eq!(err.status(), 503);
        assert_eq!(err.tag(), "draining");
        engine.shutdown();
    }

    #[test]
    fn unknown_routes_and_methods() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let ctx = RouteCtx { engine: &engine, quota: None, draining: &draining };
        assert_eq!(dispatch(&get("/nope"), "ip", &ctx).unwrap_err().status(), 404);
        assert_eq!(
            dispatch(&request("POST", "/healthz", b""), "ip", &ctx).unwrap_err().status(),
            405
        );
        assert_eq!(
            dispatch(&request("GET", "/v1/project", b""), "ip", &ctx).unwrap_err().status(),
            405
        );
        assert_eq!(
            dispatch(&request("POST", "/v1/encode/banana", b"{}"), "ip", &ctx)
                .unwrap_err()
                .status(),
            400
        );
        engine.shutdown();
    }

    #[test]
    fn project_route_round_trips_bit_identically() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let ctx = RouteCtx { engine: &engine, quota: None, draining: &draining };
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let y = Matrix::<f64>::randn(16, 8, &mut rng);
        let req = ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y.clone());
        let body = wire::project_request_body(&req);
        let Action::Respond { status, body } =
            dispatch(&request("POST", "/v1/project", body.as_bytes()), "ip", &ctx).unwrap()
        else {
            panic!("project must respond")
        };
        assert_eq!(status, 200);
        let over_wire = wire::decode_response(&body).unwrap();
        let direct = engine.submit_wait(req).unwrap();
        let (a, b) =
            (over_wire.payload.as_f64().unwrap(), direct.payload.as_f64().unwrap());
        assert_eq!(a.max_abs_diff(b), 0.0, "wire result must be bit-identical");
        engine.shutdown();
    }

    #[test]
    fn bad_bodies_are_400_not_panics() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let ctx = RouteCtx { engine: &engine, quota: None, draining: &draining };
        for body in [&b"not json"[..], b"{}", b"{\"kind\":\"bogus\"}", b"\xff\xfe"] {
            let err =
                dispatch(&request("POST", "/v1/project", body), "ip", &ctx).unwrap_err();
            assert_eq!(err.status(), 400, "body {body:?}");
            assert_eq!(err.tag(), "bad_request");
        }
        engine.shutdown();
    }

    #[test]
    fn quota_and_overload_tags_differ() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let gate = QuotaGate::new(0.01, 1.0);
        let ctx = RouteCtx { engine: &engine, quota: Some(&gate), draining: &draining };
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let y = Matrix::<f64>::randn(4, 4, &mut rng);
        let body = wire::project_request_body(&ProjectionRequest::f64(
            ProjectionKind::BilevelL1Inf,
            1.0,
            y,
        ));
        let req = request("POST", "/v1/project", body.as_bytes());
        assert!(dispatch(&req, "1.2.3.4", &ctx).is_ok());
        let err = dispatch(&req, "1.2.3.4", &ctx).unwrap_err();
        assert_eq!(err.status(), 429);
        assert_eq!(err.tag(), "quota");
        assert!(err.retry_after().unwrap() > Duration::ZERO);
        let headers = err.headers();
        assert!(headers.iter().any(|(k, _)| k == "Retry-After"));
        assert!(headers.iter().any(|(k, _)| k == "X-Retry-After-Micros"));
        // a different client is unaffected
        assert!(dispatch(&req, "5.6.7.8", &ctx).is_ok());
        // the overload variant uses a different tag (constructed directly:
        // provoking real queue overload deterministically is the
        // integration suite's job)
        let overload = RouteError::Overloaded { retry_after: Duration::from_micros(300) };
        assert_eq!(overload.status(), 429);
        assert_eq!(overload.tag(), "overloaded");
        engine.shutdown();
    }

    #[test]
    fn circuit_and_worker_failure_map_to_typed_errors() {
        let open = submit_error(SubmitError::CircuitOpen {
            model: 9,
            retry_after: Duration::from_millis(250),
        });
        assert_eq!(open.status(), 503);
        assert_eq!(open.tag(), "circuit_open");
        assert_eq!(open.retry_after(), Some(Duration::from_millis(250)));
        assert!(open.headers().iter().any(|(k, _)| k == "Retry-After"));
        assert!(open.message().contains("model 9"));
        let failed =
            submit_error(SubmitError::Failed(crate::serve::JobError::WorkerPanic { shard: 2 }));
        assert_eq!(failed.status(), 500);
        assert_eq!(failed.tag(), "worker_panic");
        assert!(failed.retry_after().is_none());
        assert!(failed.message().contains("shard 2"));
    }

    #[test]
    fn client_id_header_overrides_peer_key() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let gate = QuotaGate::new(0.01, 1.0);
        let ctx = RouteCtx { engine: &engine, quota: Some(&gate), draining: &draining };
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let y = Matrix::<f64>::randn(4, 4, &mut rng);
        let body = wire::project_request_body(&ProjectionRequest::f64(
            ProjectionKind::BilevelL1Inf,
            1.0,
            y,
        ));
        let mut req = request("POST", "/v1/project", body.as_bytes());
        req.headers.push(("x-client-id".into(), "tenant-a".into()));
        assert!(dispatch(&req, "1.2.3.4", &ctx).is_ok());
        // same header from a different peer shares the bucket
        let err = dispatch(&req, "9.9.9.9", &ctx).unwrap_err();
        let RouteError::QuotaExceeded { client, .. } = err else { panic!("expected quota") };
        assert_eq!(client, "tenant-a");
        engine.shutdown();
    }

    #[test]
    fn mid_drain_submit_is_typed_503() {
        let engine = small_engine();
        let draining = AtomicBool::new(true);
        let ctx = RouteCtx { engine: &engine, quota: None, draining: &draining };
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let y = Matrix::<f64>::randn(4, 4, &mut rng);
        let body = wire::project_request_body(&ProjectionRequest::f64(
            ProjectionKind::BilevelL1Inf,
            1.0,
            y,
        ));
        let err = dispatch(&request("POST", "/v1/project", body.as_bytes()), "ip", &ctx)
            .unwrap_err();
        assert_eq!(err, RouteError::Draining);
        assert_eq!(err.status(), 503);
        assert!(err.body().contains("draining"));
        // stats remain readable while draining
        assert!(dispatch(&get("/v1/stats"), "ip", &ctx).is_ok());
        engine.shutdown();
    }

    #[test]
    fn sse_stream_emits_monotonic_seq_and_terminates_on_limit() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let mut buf = Vec::new();
        stream_stats(&mut buf, &engine, &draining, Duration::from_millis(1), Some(3)).unwrap();
        let mut r = std::io::Cursor::new(&buf);
        let limits = super::super::http::HttpLimits::default();
        let (status, _) = super::super::http::read_response_head(&mut r, &limits).unwrap();
        assert_eq!(status, 200);
        let mut text = String::new();
        while let Some(chunk) = super::super::http::read_chunk(&mut r).unwrap() {
            text.push_str(std::str::from_utf8(&chunk).unwrap());
        }
        let seqs: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("data: {\"seq\":"))
            .map(|l| {
                let rest = &l["data: {\"seq\":".len()..];
                rest[..rest.find(',').unwrap()].parse().unwrap()
            })
            .collect();
        assert_eq!(seqs, vec![0, 1, 2]);
        engine.shutdown();
    }

    #[test]
    fn sse_stream_ends_with_drain_event() {
        let engine = small_engine();
        let draining = AtomicBool::new(true); // drained before streaming
        let mut buf = Vec::new();
        stream_stats(&mut buf, &engine, &draining, Duration::from_millis(1), None).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("event: drain"), "{text}");
        engine.shutdown();
    }

    #[test]
    fn events_route_parses_limit() {
        let engine = small_engine();
        let draining = AtomicBool::new(false);
        let ctx = RouteCtx { engine: &engine, quota: None, draining: &draining };
        assert!(matches!(
            dispatch(&get("/v1/events?n=5"), "ip", &ctx),
            Ok(Action::StreamStats { limit: Some(5) })
        ));
        assert!(matches!(
            dispatch(&get("/v1/events"), "ip", &ctx),
            Ok(Action::StreamStats { limit: None })
        ));
        assert_eq!(dispatch(&get("/v1/events?n=x"), "ip", &ctx).unwrap_err().status(), 400);
        assert!(matches!(
            dispatch(&request("POST", "/v1/drain", b""), "ip", &ctx),
            Ok(Action::BeginDrain { .. })
        ));
        engine.shutdown();
    }
}
