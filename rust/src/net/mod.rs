//! Dependency-free HTTP/1.1 front-end for the serve engine.
//!
//! Everything here is built on `std::net` — no async runtime, no serde,
//! no HTTP crate (the vendored registry is offline). The layering:
//!
//! | module | role |
//! |---|---|
//! | [`http`] | HTTP/1.1 parse/serialize: keep-alive, chunked transfer, hardened with read timeouts and header/body size caps |
//! | [`wire`] | JSON encode/decode for payloads, responses, stats — bit-exact `f32`/`f64` round trips via shortest-representation formatting |
//! | [`quota`] | per-client token-bucket admission ([`QuotaGate`]) |
//! | [`routes`] | URL → engine dispatch, typed [`RouteError`] → status/headers, SSE stats streaming |
//! | [`server`] | bind/accept/drain lifecycle ([`Server`]), thread-per-connection |
//!
//! Routes:
//!
//! * `POST /v1/project` — run one projection (`Engine::submit_wait`)
//! * `POST /v1/encode/{model}` — sparse encode through a registered model
//! * `GET /v1/stats` — engine counters snapshot (JSON)
//! * `GET /v1/models` — registered encoder inventory
//! * `GET /v1/events[?n=K]` — Server-Sent Events stream of stats snapshots
//! * `GET /healthz` — 200 with the engine's health machine
//!   (`ok`/`degraded` + reasons), or 503 once draining
//! * `POST /v1/drain` — begin graceful drain
//!
//! Backpressure surfaces as HTTP 429 with both `Retry-After` (whole
//! seconds) and `X-Retry-After-Micros` (exact) headers; quota rejections
//! and engine-queue overload carry distinct error tags so clients can
//! tell "slow down" from "server is saturated". Recovery failures are
//! typed the same way: an open per-model circuit breaker is 503
//! `circuit_open` (with the same retry headers) and a worker panic that
//! killed an accepted job is 500 `worker_panic`.

pub mod http;
pub mod quota;
pub mod routes;
pub mod server;
pub mod wire;

pub use http::{HttpError, HttpLimits, Request, Response};
pub use quota::QuotaGate;
pub use routes::{dispatch, stream_stats, Action, RouteCtx, RouteError};
pub use server::{NetError, NetReport, Server};
pub use wire::Json;
