//! Server lifecycle: bind, accept, serve, drain.
//!
//! Thread-per-connection over `std::net::TcpListener`, matching the
//! repo's hand-rolled threading style (no async runtime): one named
//! accept thread, one named handler thread per connection, bounded by
//! `max_connections` (excess connections are refused with 503 without
//! spawning).
//!
//! **Graceful drain** (`POST /v1/drain` or [`Server::drain`]):
//! 1. the draining flag flips (new submits on live connections get 503,
//!    `/healthz` reports 503 — load balancers stop routing here);
//! 2. a self-connect pokes the accept loop awake so it stops accepting;
//! 3. every connection's **read** half is shut down — handlers blocked
//!    waiting for the next keep-alive request wake up with EOF and exit,
//!    while responses still in flight keep their write half and complete.
//! No accepted request is abandoned: a request that was fully read and
//! dispatched always gets its response written. This composes with
//! `Engine::swap_model` hot-swaps (admission resolves encoder `Arc`s), and
//! the `net_integration` suite drives both at once under client traffic.
//!
//! Bind failures are typed ([`NetError`]): a malformed listen address, a
//! port already in use, and other bind errors each render a clear message
//! instead of a panic.
//!
//! Sockets carry **both** timeouts: `set_read_timeout` (slow senders →
//! 408) and `set_write_timeout` (slow readers → the response write fails
//! and is counted in [`NetReport::write_timeouts`]). The
//! [`crate::fault`] site `conn.reset` wraps each connection's write half
//! (`FaultStream`) to sever it after an injected byte budget —
//! chaos-testing the drain guarantee that no *accepted* request is
//! silently lost.

use std::collections::HashMap;
use std::fmt;
use std::io::{self, BufReader};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::HttpConfig;
use crate::fault::{self, FaultSite};
use crate::metrics::Counter;
use crate::serve::Engine;
use crate::sync::lock_unpoisoned;

use super::http::{self, HttpError, HttpLimits};
use super::quota::QuotaGate;
use super::routes::{dispatch, stream_stats, Action, RouteCtx, RouteError};
use super::wire;

/// Why the front-end could not start (or perform I/O).
#[derive(Debug)]
pub enum NetError {
    /// The listen address did not parse as numeric `ip:port`.
    MalformedAddr { addr: String, source: String },
    /// Another process (or server) already owns the port.
    AddrInUse { addr: String },
    /// Any other bind failure (permissions, missing interface…).
    Bind { addr: String, source: String },
    /// Invalid `[serve.http]` configuration.
    Config(String),
    Io(String),
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::MalformedAddr { addr, source } => {
                write!(f, "malformed listen address {addr:?} (expected ip:port): {source}")
            }
            Self::AddrInUse { addr } => {
                write!(f, "listen address {addr:?} is already in use")
            }
            Self::Bind { addr, source } => write!(f, "binding {addr:?}: {source}"),
            Self::Config(msg) => write!(f, "invalid http config: {msg}"),
            Self::Io(msg) => write!(f, "network error: {msg}"),
        }
    }
}

impl std::error::Error for NetError {}

/// Front-end counters (relaxed atomics, server lifetime).
#[derive(Debug, Default)]
struct NetCounters {
    accepted: Counter,
    refused: Counter,
    served_ok: Counter,
    served_err: Counter,
    quota_rejected: Counter,
    overloaded: Counter,
    write_timeouts: Counter,
}

/// Point-in-time snapshot of the front-end counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetReport {
    /// Connections accepted and handed to a handler thread.
    pub accepted_connections: u64,
    /// Connections refused at the `max_connections` cap (503, no thread).
    pub refused_connections: u64,
    /// Requests answered 2xx.
    pub served_ok: u64,
    /// Requests answered with an error status.
    pub served_err: u64,
    /// 429s from per-client quota exhaustion.
    pub quota_rejected: u64,
    /// 429s from engine queue overload.
    pub overloaded: u64,
    /// Response writes abandoned because the peer read too slowly
    /// (`set_write_timeout` expired mid-response).
    pub write_timeouts: u64,
}

impl fmt::Display for NetReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "http: conns {} (+{} refused) | ok {} | err {} | 429 quota {} | 429 overload {} | write timeouts {}",
            self.accepted_connections,
            self.refused_connections,
            self.served_ok,
            self.served_err,
            self.quota_rejected,
            self.overloaded,
            self.write_timeouts,
        )
    }
}

/// State shared by the accept loop and every handler thread.
struct Shared {
    engine: Arc<Engine>,
    cfg: HttpConfig,
    limits: HttpLimits,
    quota: Option<QuotaGate>,
    draining: AtomicBool,
    counters: NetCounters,
    /// Read-half clones of live connections, for drain wake-up.
    conns: Mutex<HashMap<u64, TcpStream>>,
    handlers: Mutex<Vec<JoinHandle<()>>>,
    next_conn: AtomicU64,
    active: AtomicUsize,
    addr: SocketAddr,
}

impl Shared {
    fn report(&self) -> NetReport {
        NetReport {
            accepted_connections: self.counters.accepted.get(),
            refused_connections: self.counters.refused.get(),
            served_ok: self.counters.served_ok.get(),
            served_err: self.counters.served_err.get(),
            quota_rejected: self.counters.quota_rejected.get(),
            overloaded: self.counters.overloaded.get(),
            write_timeouts: self.counters.write_timeouts.get(),
        }
    }
}

/// The HTTP front-end. Bind with [`Server::start`]; stop with
/// [`Server::join`] (drains first). Dropping without joining drains and
/// joins too — a server can never leak threads.
pub struct Server {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Validate `cfg`, bind the listener, and spawn the accept loop.
    pub fn start(engine: Arc<Engine>, cfg: &HttpConfig) -> Result<Server, NetError> {
        cfg.validate().map_err(NetError::Config)?;
        let addr: SocketAddr = cfg.listen.parse().map_err(|e: std::net::AddrParseError| {
            NetError::MalformedAddr { addr: cfg.listen.clone(), source: e.to_string() }
        })?;
        let listener = TcpListener::bind(addr).map_err(|e| match e.kind() {
            io::ErrorKind::AddrInUse => NetError::AddrInUse { addr: cfg.listen.clone() },
            _ => NetError::Bind { addr: cfg.listen.clone(), source: e.to_string() },
        })?;
        let local = listener.local_addr().map_err(|e| NetError::Io(e.to_string()))?;
        let quota = if cfg.quota_rps > 0.0 {
            Some(QuotaGate::new(cfg.quota_rps, cfg.quota_burst))
        } else {
            None
        };
        let shared = Arc::new(Shared {
            engine,
            limits: HttpLimits {
                max_header_bytes: cfg.max_header_bytes,
                max_body_bytes: cfg.max_body_bytes,
            },
            cfg: cfg.clone(),
            quota,
            draining: AtomicBool::new(false),
            counters: NetCounters::default(),
            conns: Mutex::new(HashMap::new()),
            handlers: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            addr: local,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || accept_loop(&listener, &accept_shared))
            .map_err(|e| NetError::Io(format!("spawning accept thread: {e}")))?;
        Ok(Server { shared, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the OS-assigned port).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Connections currently being handled.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Initiate a graceful drain (idempotent; see module docs).
    pub fn drain(&self) {
        begin_drain(&self.shared);
    }

    pub fn report(&self) -> NetReport {
        self.shared.report()
    }

    /// Block until a drain has been initiated (here or via `POST
    /// /v1/drain`) and every connection has finished — the CLI's
    /// foreground wait.
    pub fn wait_for_drain(&self) {
        loop {
            if self.is_draining() && self.active_connections() == 0 {
                return;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Drain (if not already draining), join every thread, and return the
    /// final counters. In-flight requests complete first.
    pub fn join(mut self) -> NetReport {
        self.finish();
        self.shared.report()
    }

    fn finish(&mut self) {
        if self.accept.is_none() {
            return;
        }
        begin_drain(&self.shared);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        loop {
            let drained: Vec<_> = std::mem::take(&mut *lock_unpoisoned(&self.shared.handlers));
            if drained.is_empty() {
                break;
            }
            for h in drained {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Flip the drain flag (first caller only), wake the accept loop, and
/// wake handlers parked between keep-alive requests.
fn begin_drain(shared: &Shared) {
    if shared.draining.swap(true, Ordering::SeqCst) {
        return;
    }
    // Poke the accept loop out of its blocking accept(). The loop sees
    // the flag and exits; the poke connection itself is refused.
    let _ = TcpStream::connect(shared.addr);
    // Read-half shutdown: blocked reads return EOF; in-flight response
    // writes are untouched.
    for conn in lock_unpoisoned(&shared.conns).values() {
        let _ = conn.shutdown(Shutdown::Read);
    }
}

/// Best-effort one-shot error response on a connection we refuse to
/// service (over capacity or draining).
fn refuse(mut stream: TcpStream, status: u16, tag: &str, message: &str) {
    let body = wire::error_body(tag, message, None);
    let _ = http::write_response(
        &mut stream,
        status,
        "application/json",
        body.as_bytes(),
        &[],
        false,
    );
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(pair) => pair,
            Err(_) if shared.draining.load(Ordering::SeqCst) => break,
            Err(_) => continue,
        };
        if shared.draining.load(Ordering::SeqCst) {
            refuse(stream, 503, "draining", "server is draining");
            break;
        }
        if shared.active.load(Ordering::SeqCst) >= shared.cfg.max_connections {
            shared.counters.refused.inc();
            refuse(stream, 503, "capacity", "connection limit reached; retry");
            continue;
        }
        reap_finished(shared);
        let id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            lock_unpoisoned(&shared.conns).insert(id, clone);
        }
        shared.active.fetch_add(1, Ordering::SeqCst);
        shared.counters.accepted.inc();
        let conn_shared = Arc::clone(shared);
        let spawned = std::thread::Builder::new().name(format!("http-conn-{id}")).spawn(
            move || {
                handle_conn(&conn_shared, stream, peer);
                lock_unpoisoned(&conn_shared.conns).remove(&id);
                conn_shared.active.fetch_sub(1, Ordering::SeqCst);
            },
        );
        match spawned {
            Ok(h) => lock_unpoisoned(&shared.handlers).push(h),
            Err(_) => {
                // Spawn failure: undo the bookkeeping; the stream (moved
                // into the dead closure) is already gone.
                lock_unpoisoned(&shared.conns).remove(&id);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// Join handler threads that already finished (keeps the handle list from
/// growing unboundedly under connection churn).
fn reap_finished(shared: &Shared) {
    let mut handlers = lock_unpoisoned(&shared.handlers);
    let mut i = 0;
    while i < handlers.len() {
        if handlers[i].is_finished() {
            let _ = handlers.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
}

/// Write wrapper carrying the `conn.reset` fault site: bytes pass
/// through until the injected budget is spent, then every write fails
/// with `ConnectionReset` — the server-side view of a peer that vanished
/// mid-response. `reset_after: None` (the unconfigured default) is a
/// plain pass-through.
struct FaultStream<W> {
    inner: W,
    reset_after: Option<u64>,
}

impl<W: io::Write> io::Write for FaultStream<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match &mut self.reset_after {
            None => self.inner.write(buf),
            Some(left) => {
                if *left == 0 && !buf.is_empty() {
                    return Err(io::Error::new(
                        io::ErrorKind::ConnectionReset,
                        "injected fault: conn.reset",
                    ));
                }
                let allowed = (*left).min(buf.len() as u64) as usize;
                let n = self.inner.write(&buf[..allowed])?;
                *left -= n as u64;
                Ok(n)
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Record a failed response write. An expired `SO_SNDTIMEO` surfaces as
/// `WouldBlock` on Unix (`TimedOut` elsewhere); anything else is the peer
/// disconnecting, which the caller already treats as end-of-connection.
fn note_write_error(shared: &Shared, e: &io::Error) {
    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) {
        shared.counters.write_timeouts.inc();
    }
}

/// Serve one connection: keep-alive request loop until EOF, timeout,
/// `Connection: close`, a streaming route, or drain.
fn handle_conn(shared: &Shared, stream: TcpStream, peer: SocketAddr) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout()));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout()));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer =
        FaultStream { inner: stream, reset_after: fault::fire(FaultSite::ConnReset) };
    let peer_ip = peer.ip().to_string();
    loop {
        let req = match http::read_request(&mut reader, &mut writer, &shared.limits) {
            Ok(Some(req)) => req,
            // clean EOF between requests: normal keep-alive end (or drain)
            Ok(None) => return,
            Err(e) => {
                let (status, tag) = match &e {
                    HttpError::Malformed(_) => (400, "bad_request"),
                    HttpError::HeadersTooLarge => (431, "headers_too_large"),
                    HttpError::BodyTooLarge => (413, "body_too_large"),
                    HttpError::TimedOut => (408, "timeout"),
                    HttpError::UnexpectedEof | HttpError::Io(_) => return,
                };
                shared.counters.served_err.inc();
                let body = wire::error_body(tag, &e.to_string(), None);
                if let Err(we) = http::write_response(
                    &mut writer,
                    status,
                    "application/json",
                    body.as_bytes(),
                    &[],
                    false,
                ) {
                    note_write_error(shared, &we);
                }
                return;
            }
        };
        let keep = req.keep_alive();
        let ctx = RouteCtx {
            engine: &shared.engine,
            quota: shared.quota.as_ref(),
            draining: &shared.draining,
        };
        match dispatch(&req, &peer_ip, &ctx) {
            Ok(Action::Respond { status, body }) => {
                let wrote = match http::write_response(
                    &mut writer,
                    status,
                    "application/json",
                    body.as_bytes(),
                    &[],
                    keep,
                ) {
                    Ok(()) => true,
                    Err(e) => {
                        note_write_error(shared, &e);
                        false
                    }
                };
                if wrote {
                    shared.counters.served_ok.inc();
                }
                if !wrote || !keep {
                    return;
                }
            }
            Ok(Action::StreamStats { limit }) => {
                // streams own the connection; always close afterwards
                if let Err(e) = stream_stats(
                    &mut writer,
                    &shared.engine,
                    &shared.draining,
                    shared.cfg.sse_interval(),
                    limit,
                ) {
                    note_write_error(shared, &e);
                }
                shared.counters.served_ok.inc();
                return;
            }
            Ok(Action::BeginDrain { body }) => {
                if let Err(e) = http::write_response(
                    &mut writer,
                    200,
                    "application/json",
                    body.as_bytes(),
                    &[],
                    false,
                ) {
                    note_write_error(shared, &e);
                }
                shared.counters.served_ok.inc();
                begin_drain(shared);
                return;
            }
            Err(err) => {
                match &err {
                    RouteError::QuotaExceeded { .. } => shared.counters.quota_rejected.inc(),
                    RouteError::Overloaded { .. } => shared.counters.overloaded.inc(),
                    _ => {}
                }
                shared.counters.served_err.inc();
                let wrote = match http::write_response(
                    &mut writer,
                    err.status(),
                    "application/json",
                    err.body().as_bytes(),
                    &err.headers(),
                    keep,
                ) {
                    Ok(()) => true,
                    Err(e) => {
                        note_write_error(shared, &e);
                        false
                    }
                };
                if !wrote || !keep {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use std::io::Write as _;

    fn test_http_cfg(listen: &str) -> HttpConfig {
        HttpConfig { listen: listen.into(), read_timeout_ms: 2_000, ..HttpConfig::default() }
    }

    fn small_engine() -> Arc<Engine> {
        Arc::new(
            Engine::start(&ServeConfig {
                shards: 1,
                workers_per_shard: 1,
                cache_capacity: 8,
                ..ServeConfig::default()
            })
            .unwrap(),
        )
    }

    #[test]
    fn fault_stream_passes_through_then_resets() {
        let mut fs = FaultStream { inner: Vec::new(), reset_after: Some(5) };
        assert_eq!(fs.write(b"abc").unwrap(), 3);
        assert_eq!(fs.write(b"defg").unwrap(), 2, "budget caps the partial write");
        let err = fs.write(b"hi").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        assert_eq!(fs.inner, b"abcde", "bytes up to the budget must be delivered");
        let mut clean = FaultStream { inner: Vec::new(), reset_after: None };
        assert_eq!(clean.write(b"hello").unwrap(), 5);
        clean.flush().unwrap();
    }

    #[test]
    fn malformed_listen_addr_is_typed() {
        let engine = small_engine();
        for bad in ["not-an-addr", "127.0.0.1", "localhost:8080", "1.2.3.4:notaport"] {
            let err = Server::start(Arc::clone(&engine), &test_http_cfg(bad)).unwrap_err();
            assert!(
                matches!(err, NetError::MalformedAddr { .. }),
                "{bad}: got {err:?}"
            );
            assert!(err.to_string().contains(bad), "message must name the address: {err}");
        }
    }

    #[test]
    fn bind_in_use_is_typed() {
        let engine = small_engine();
        let holder = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = holder.local_addr().unwrap();
        let err =
            Server::start(Arc::clone(&engine), &test_http_cfg(&addr.to_string())).unwrap_err();
        assert!(matches!(err, NetError::AddrInUse { .. }), "got {err:?}");
        assert!(err.to_string().contains("in use"));
    }

    #[test]
    fn invalid_http_config_is_typed() {
        let engine = small_engine();
        let cfg = HttpConfig { max_connections: 0, ..test_http_cfg("127.0.0.1:0") };
        let err = Server::start(engine, &cfg).unwrap_err();
        assert!(matches!(err, NetError::Config(_)), "got {err:?}");
    }

    #[test]
    fn serves_healthz_then_drains_cleanly() {
        let engine = small_engine();
        let server = Server::start(Arc::clone(&engine), &test_http_cfg("127.0.0.1:0")).unwrap();
        let addr = server.addr();
        assert_ne!(addr.port(), 0, ":0 must resolve to a real port");

        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        http::write_request(&mut conn, "GET", "/healthz", &[], b"").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let resp = http::read_response(&mut reader, &HttpLimits::default()).unwrap();
        assert_eq!(resp.status, 200);
        assert!(String::from_utf8_lossy(&resp.body).contains("ok"));

        // keep-alive: a second request on the same connection works
        http::write_request(&mut conn, "GET", "/v1/stats", &[], b"").unwrap();
        let resp = http::read_response(&mut reader, &HttpLimits::default()).unwrap();
        assert_eq!(resp.status, 200);

        // drain over the wire
        http::write_request(&mut conn, "POST", "/v1/drain", &[], b"").unwrap();
        let resp = http::read_response(&mut reader, &HttpLimits::default()).unwrap();
        assert_eq!(resp.status, 200);
        server.wait_for_drain();
        let report = server.join();
        assert!(report.served_ok >= 3, "{report:?}");
        assert_eq!(report.refused_connections, 0);

        // listener is gone: new connections are refused by the OS
        assert!(TcpStream::connect(addr).is_err() || {
            // (a racing late accept may still succeed at the TCP level on
            // some kernels; any such socket is immediately dead)
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
            s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").ok();
            let mut buf = [0u8; 1];
            matches!(std::io::Read::read(&mut s, &mut buf), Ok(0) | Err(_))
        });
        // the engine is still ours to shut down
        let engine = Arc::try_unwrap(engine).ok().expect("server must release its engine Arc");
        engine.shutdown();
    }

    #[test]
    fn malformed_wire_bytes_get_400_not_a_hang() {
        let engine = small_engine();
        let server = Server::start(Arc::clone(&engine), &test_http_cfg("127.0.0.1:0")).unwrap();
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        conn.write_all(b"THIS IS NOT HTTP\r\n\r\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let resp = http::read_response(&mut reader, &HttpLimits::default()).unwrap();
        assert_eq!(resp.status, 400);
        drop(conn);
        server.join();
        Arc::try_unwrap(engine).ok().unwrap().shutdown();
    }

    #[test]
    fn drop_without_join_drains() {
        let engine = small_engine();
        let server = Server::start(Arc::clone(&engine), &test_http_cfg("127.0.0.1:0")).unwrap();
        let addr = server.addr();
        let _ = TcpStream::connect(addr).unwrap();
        drop(server); // must not hang or leak threads
        Arc::try_unwrap(engine).ok().expect("drop must release the engine Arc").shutdown();
    }
}
