//! Repo-aware static analysis (`bilevel audit`).
//!
//! A dependency-free lint pass over this repository's own sources: a
//! lightweight Rust lexer ([`lexer`]) that strips strings and comments so
//! token scans cannot misfire, and a rule engine ([`rules`]) with
//! per-rule allowlists producing typed [`Finding`]s with `file:line`
//! spans. The same rules run three ways:
//!
//! * `bilevel audit` — CLI entry point, nonzero exit on any finding;
//! * `cargo test --test audit_integration` — the repo must stay clean
//!   under plain `cargo test`;
//! * unit fixtures in [`rules`] — each rule is pinned to fire exactly
//!   once on a minimal violation and never inside strings or comments.
//!
//! See `EXPERIMENTS.md` §Static analysis for the rule table, rationale,
//! and the allowlist policy.

pub mod lexer;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation, anchored to a source span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Stable rule identifier (e.g. `safety-comment`).
    pub rule: &'static str,
    /// Repo-relative path with unix separators.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable explanation of the violation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// The outcome of [`audit_repo`].
#[derive(Debug, Clone)]
pub struct AuditReport {
    /// All findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl AuditReport {
    /// True when the audit is clean.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Run every audit rule over the repository rooted at `root` (the
/// directory containing `Cargo.toml`).
///
/// Scans `rust/src/` recursively plus the top level of `rust/tests/` and
/// `rust/benches/`, then checks `Cargo.toml` target registration.
pub fn audit_repo(root: &Path) -> io::Result<AuditReport> {
    let mut files = Vec::new();
    collect_rs(&root.join("rust/src"), &mut files)?;
    collect_rs(&root.join("rust/tests"), &mut files)?;
    collect_rs(&root.join("rust/benches"), &mut files)?;
    files.sort();

    let mut findings = Vec::new();
    for file in &files {
        let src = fs::read_to_string(file)?;
        findings.extend(rules::check_source(&rel_unix(root, file), &src));
    }

    let cargo = fs::read_to_string(root.join("Cargo.toml"))?;
    let tests = file_names(&root.join("rust/tests"))?;
    let benches = file_names(&root.join("rust/benches"))?;
    findings.extend(rules::check_registration(&cargo, &tests, &benches));

    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Ok(AuditReport { findings, files_scanned: files.len() })
}

/// Render a report the way compilers do: one `file:line: [rule] message`
/// per finding, then a one-line summary.
pub fn render(report: &AuditReport) -> String {
    let mut out = String::new();
    for finding in &report.findings {
        out.push_str(&finding.to_string());
        out.push('\n');
    }
    out.push_str(&format!(
        "audit: {} file(s) scanned, {} finding(s)\n",
        report.files_scanned,
        report.findings.len()
    ));
    out
}

/// Recursively collect `.rs` files under `dir` (no-op if it is absent, so
/// the audit degrades gracefully on partial checkouts).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Top-level `.rs` file names (not paths) in `dir`, sorted.
fn file_names(dir: &Path) -> io::Result<Vec<String>> {
    let mut names = Vec::new();
    if !dir.is_dir() {
        return Ok(names);
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_file() && path.extension().is_some_and(|e| e == "rs") {
            if let Some(name) = path.file_name() {
                names.push(name.to_string_lossy().into_owned());
            }
        }
    }
    names.sort();
    Ok(names)
}

/// Repo-relative unix-separator rendering of `path`.
fn rel_unix(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn findings_render_like_compiler_diagnostics() {
        let f = Finding {
            rule: rules::RULE_SAFETY,
            path: "rust/src/kernels/avx2.rs".to_string(),
            line: 42,
            message: "msg".to_string(),
        };
        assert_eq!(f.to_string(), "rust/src/kernels/avx2.rs:42: [safety-comment] msg");
    }

    #[test]
    fn render_includes_a_summary_line() {
        let report = AuditReport { findings: Vec::new(), files_scanned: 3 };
        assert!(report.is_clean());
        assert!(render(&report).contains("3 file(s) scanned, 0 finding(s)"));
    }
}
