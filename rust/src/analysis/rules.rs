//! The audit rules.
//!
//! Each rule is derived from a real hazard in this codebase (see
//! `EXPERIMENTS.md` §Static analysis for the full table):
//!
//! * **safety-comment** — every `unsafe` keyword must be immediately
//!   preceded (same line, or a contiguous comment block above, attributes
//!   allowed in between) by a comment containing `SAFETY` (doc-comment
//!   `# Safety` sections count).
//! * **unsafe-allowlist** — `unsafe` may only appear in the files of
//!   [`UNSAFE_ALLOWLIST`]: the SIMD kernels, the dispatch cast shims, the
//!   parking pool, the parallel column splitters (bi-level and the
//!   multilevel tree), and the counting allocator used by the zero-alloc
//!   test.
//! * **lock-unwrap** — non-test code under `rust/src/` must not call
//!   `.lock().unwrap()`; it must use the poison-recovering helpers in
//!   [`crate::sync`] so one panicking thread cannot cascade into
//!   process-wide panics.
//! * **registered-target** — every file under `rust/tests/` and
//!   `rust/benches/` must be registered in `Cargo.toml`; with
//!   `autotests = false` an unregistered suite silently never runs.
//! * **banned-macro** — no `todo!` / `unimplemented!` / `dbg!` under
//!   `rust/src/`.
//! * **clippy-deny** — every module declared in `rust/src/lib.rs` carries
//!   `#[deny(clippy::all)]` (or a comment containing `clippy-exempt:`
//!   explaining why not).
//!
//! All token scans run on the lexer's code channel, so nothing fires on
//! text inside string literals or comments.

use super::lexer::{lex, Lexed};
use super::Finding;

/// Rule names (stable identifiers used in findings and docs).
pub const RULE_SAFETY: &str = "safety-comment";
/// See [`RULE_SAFETY`].
pub const RULE_ALLOWLIST: &str = "unsafe-allowlist";
/// See [`RULE_SAFETY`].
pub const RULE_LOCK: &str = "lock-unwrap";
/// See [`RULE_SAFETY`].
pub const RULE_REGISTERED: &str = "registered-target";
/// See [`RULE_SAFETY`].
pub const RULE_BANNED: &str = "banned-macro";
/// See [`RULE_SAFETY`].
pub const RULE_CLIPPY: &str = "clippy-deny";

/// Files (repo-relative, unix separators) allowed to contain `unsafe`
/// code. Everything here is either a SIMD kernel reached only behind a
/// runtime CPU-feature check, a TypeId-guarded cast shim, the parking
/// pool's scoped-borrow machinery, a disjoint-chunk column splitter (the
/// bi-level parallel path and the multilevel tree's pooled subtree stages
/// share the same SendPtr idiom), or the counting global allocator of the
/// zero-alloc test.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/kernels/avx2.rs",
    "rust/src/kernels/dispatch.rs",
    "rust/src/kernels/neon.rs",
    "rust/src/kernels/pool.rs",
    "rust/src/projection/bilevel/parallel.rs",
    "rust/src/projection/multilevel/mod.rs",
    "rust/tests/kernels_alloc.rs",
];

/// Run every per-file rule that applies to `rel_path` over `src`.
///
/// `rel_path` is repo-relative with unix separators (`rust/src/...`);
/// which rules apply depends on it: the unsafe rules run everywhere,
/// lock/banned-macro rules only under `rust/src/`, and the clippy-deny
/// rule only on `rust/src/lib.rs`.
pub fn check_source(rel_path: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let mask = test_region_mask(&lexed);
    let mut findings = Vec::new();
    unsafe_rules(rel_path, &lexed, &mut findings);
    if rel_path.starts_with("rust/src/") {
        lock_unwrap_rule(rel_path, &lexed, &mask, &mut findings);
        banned_macro_rule(rel_path, &lexed, &mut findings);
    }
    if rel_path == "rust/src/lib.rs" {
        clippy_deny_rule(rel_path, &lexed, &mut findings);
    }
    findings
}

/// Rules 1 + 2: SAFETY coverage for every `unsafe` keyword, and the
/// file-level allowlist.
fn unsafe_rules(rel_path: &str, lexed: &Lexed, findings: &mut Vec<Finding>) {
    let allowlisted = UNSAFE_ALLOWLIST.contains(&rel_path);
    let mut first_unsafe_line = None;
    for i in 0..lexed.len() {
        if word_positions(&lexed.code[i], "unsafe").is_empty() {
            continue;
        }
        first_unsafe_line.get_or_insert(i);
        if !safety_covered(lexed, i) {
            findings.push(Finding {
                rule: RULE_SAFETY,
                path: rel_path.to_string(),
                line: i + 1,
                message: "`unsafe` without an immediately preceding `// SAFETY:` comment"
                    .to_string(),
            });
        }
    }
    if let (false, Some(line)) = (allowlisted, first_unsafe_line) {
        findings.push(Finding {
            rule: RULE_ALLOWLIST,
            path: rel_path.to_string(),
            line: line + 1,
            message: "file contains `unsafe` but is not in analysis::rules::UNSAFE_ALLOWLIST"
                .to_string(),
        });
    }
}

/// Is the `unsafe` on `line` covered by a SAFETY comment?
///
/// Accepted: a comment containing `safety` (case-insensitive) on the same
/// line, or a contiguous comment block directly above the line — attribute
/// lines (`#[...]` / `#![...]`) may sit between the comment and the item,
/// so `/// # Safety` docs above `#[target_feature]` functions count.
fn safety_covered(lexed: &Lexed, line: usize) -> bool {
    if has_safety(&lexed.comment[line]) {
        return true;
    }
    let mut i = line;
    while i > 0 {
        i -= 1;
        let code = lexed.code[i].trim();
        let comment = lexed.comment[i].trim();
        if code.starts_with("#[") || code.starts_with("#![") {
            continue;
        }
        if code.is_empty() && !comment.is_empty() {
            if has_safety(comment) {
                return true;
            }
            continue;
        }
        // A code line or a blank line ends the contiguous block.
        return false;
    }
    false
}

fn has_safety(comment: &str) -> bool {
    comment.to_ascii_lowercase().contains("safety")
}

/// Rule 3: `.lock()` immediately followed (whitespace allowed, including
/// line breaks) by `.unwrap()` outside `#[cfg(test)]` regions.
fn lock_unwrap_rule(rel_path: &str, lexed: &Lexed, mask: &[bool], findings: &mut Vec<Finding>) {
    let text = lexed.code_text();
    let bytes = text.as_bytes();
    for (at, _) in text.match_indices(".lock()") {
        let mut j = at + ".lock()".len();
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        if !text[j..].starts_with(".unwrap()") {
            continue;
        }
        let line = text[..at].matches('\n').count();
        if mask[line] {
            continue;
        }
        findings.push(Finding {
            rule: RULE_LOCK,
            path: rel_path.to_string(),
            line: line + 1,
            message: "`.lock().unwrap()` panic-cascades on poison; use sync::lock_unpoisoned"
                .to_string(),
        });
    }
}

/// Rule 5: `todo!` / `unimplemented!` / `dbg!` anywhere under `rust/src/`
/// (test modules included — debug scaffolding must not land at all).
fn banned_macro_rule(rel_path: &str, lexed: &Lexed, findings: &mut Vec<Finding>) {
    for mac in ["todo!", "unimplemented!", "dbg!"] {
        for (i, code) in lexed.code.iter().enumerate() {
            if word_positions(code, mac).is_empty() {
                continue;
            }
            findings.push(Finding {
                rule: RULE_BANNED,
                path: rel_path.to_string(),
                line: i + 1,
                message: format!("`{mac}` must not appear in library code"),
            });
        }
    }
}

/// Rule 6: every `pub mod` declared in `lib.rs` is pinned to
/// `#[deny(clippy::all)]` or carries a `clippy-exempt:` comment.
fn clippy_deny_rule(rel_path: &str, lexed: &Lexed, findings: &mut Vec<Finding>) {
    for i in 0..lexed.len() {
        let code = lexed.code[i].trim();
        if !code.starts_with("pub mod ") {
            continue;
        }
        if !clippy_covered(lexed, i) {
            findings.push(Finding {
                rule: RULE_CLIPPY,
                path: rel_path.to_string(),
                line: i + 1,
                message: "module not pinned to deny(clippy::all) and no clippy-exempt: note"
                    .to_string(),
            });
        }
    }
}

fn clippy_covered(lexed: &Lexed, line: usize) -> bool {
    let mut i = line;
    while i > 0 {
        i -= 1;
        let code = lexed.code[i].trim();
        let comment = lexed.comment[i].trim();
        if code.starts_with("#[") {
            if code.contains("deny(clippy::all)") {
                return true;
            }
            continue;
        }
        if code.is_empty() && !comment.is_empty() {
            if comment.contains("clippy-exempt:") {
                return true;
            }
            continue;
        }
        return false;
    }
    false
}

/// Rule 4: every top-level file in `rust/tests/` and `rust/benches/` must
/// be registered as a `path = "..."` target in `Cargo.toml`, and the
/// manifest must keep auto-discovery off (so the registration list *is*
/// the truth about what runs).
pub fn check_registration(
    cargo_toml: &str,
    test_files: &[String],
    bench_files: &[String],
) -> Vec<Finding> {
    let mut registered = Vec::new();
    let mut autotests_off = false;
    let mut autobenches_off = false;
    for line in cargo_toml.lines() {
        let t = line.trim();
        let squashed: String = t.chars().filter(|c| !c.is_whitespace()).collect();
        if squashed == "autotests=false" {
            autotests_off = true;
        }
        if squashed == "autobenches=false" {
            autobenches_off = true;
        }
        if let Some(rest) = t.strip_prefix("path") {
            if let Some(eq) = rest.trim_start().strip_prefix('=') {
                if let Some(v) = extract_quoted(eq) {
                    registered.push(v);
                }
            }
        }
    }
    let mut findings = Vec::new();
    for (flag, name) in [(autotests_off, "autotests"), (autobenches_off, "autobenches")] {
        if !flag {
            findings.push(Finding {
                rule: RULE_REGISTERED,
                path: "Cargo.toml".to_string(),
                line: 1,
                message: format!("{name} = false missing; target auto-discovery must stay off"),
            });
        }
    }
    for (dir, files) in [("rust/tests", test_files), ("rust/benches", bench_files)] {
        for f in files {
            let rel = format!("{dir}/{f}");
            if !registered.iter().any(|r| r == &rel) {
                findings.push(Finding {
                    rule: RULE_REGISTERED,
                    path: rel,
                    line: 1,
                    message: "not registered in Cargo.toml; with auto-discovery off it never runs"
                        .to_string(),
                });
            }
        }
    }
    findings
}

/// First quoted value in `s`, if any.
fn extract_quoted(s: &str) -> Option<String> {
    let open = s.find('"')?;
    let rest = &s[open + 1..];
    let close = rest.find('"')?;
    Some(rest[..close].to_string())
}

/// Per-line mask of `#[cfg(test)]` regions: from the attribute line to the
/// closing brace of the item it gates (brace counting on the code channel,
/// where string/char contents are already blanked).
fn test_region_mask(lexed: &Lexed) -> Vec<bool> {
    let n = lexed.len();
    let mut mask = vec![false; n];
    let mut i = 0;
    while i < n {
        if !lexed.code[i].trim_start().starts_with("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0usize;
        let mut opened = false;
        let mut j = i;
        while j < n {
            mask[j] = true;
            for ch in lexed.code[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth = depth.saturating_sub(1),
                    _ => {}
                }
            }
            if opened && depth == 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Word-boundary occurrences of `word` in `line` (identifier characters on
/// either side disqualify a match, so e.g. a keyword embedded in a longer
/// identifier does not count).
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_word_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_word_byte(bytes[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        start = end;
    }
    out
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    const KERNEL_PATH: &str = "rust/src/kernels/avx2.rs";
    const PLAIN_PATH: &str = "rust/src/serve/engine.rs";

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_without_safety_comment_is_one_finding() {
        let src = "pub fn f(x: &[f64]) -> f64 {\n    unsafe { *x.get_unchecked(0) }\n}\n";
        let findings = check_source(KERNEL_PATH, src);
        assert_eq!(rules_of(&findings), [RULE_SAFETY]);
        assert_eq!(findings[0].line, 2);
    }

    #[test]
    fn safety_comment_on_the_line_above_clears_the_finding() {
        let src = "pub fn f(x: &[f64]) -> f64 {\n    // SAFETY: caller guarantees non-empty.\n    unsafe { *x.get_unchecked(0) }\n}\n";
        assert!(check_source(KERNEL_PATH, src).is_empty());
    }

    #[test]
    fn safety_doc_section_above_attributes_counts() {
        let src = "/// Sums four lanes.\n///\n/// # Safety\n/// Caller must have AVX2.\n#[target_feature(enable = \"avx2\")]\npub unsafe fn sum(x: &[f64]) -> f64 {\n    x[0]\n}\n";
        assert!(check_source(KERNEL_PATH, src).is_empty());
    }

    #[test]
    fn trailing_same_line_safety_comment_counts() {
        let src = "pub fn f(p: *const f64) -> f64 {\n    unsafe { *p } // SAFETY: p is valid by construction\n}\n";
        assert!(check_source(KERNEL_PATH, src).is_empty());
    }

    #[test]
    fn a_blank_line_breaks_safety_contiguity() {
        let src = "// SAFETY: too far away\n\nfn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_of(&check_source(KERNEL_PATH, src)), [RULE_SAFETY]);
    }

    #[test]
    fn unsafe_outside_the_allowlist_is_flagged() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: justified but in the wrong file\n    unsafe { *p }\n}\n";
        let findings = check_source(PLAIN_PATH, src);
        assert_eq!(rules_of(&findings), [RULE_ALLOWLIST]);
        assert_eq!(findings[0].line, 3);
    }

    #[test]
    fn unsafe_inside_a_string_or_comment_never_fires() {
        let src = "fn f() -> &'static str {\n    // this comment says unsafe and that is fine\n    \"unsafe { lock().unwrap() } todo!\"\n}\n";
        assert!(check_source(PLAIN_PATH, src).is_empty());
    }

    #[test]
    fn unsafe_embedded_in_an_identifier_never_fires() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\nfn not_unsafe_at_all() {}\n";
        assert!(check_source(PLAIN_PATH, src).is_empty());
    }

    #[test]
    fn lock_unwrap_is_one_finding_with_the_right_line() {
        let src = "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n";
        let findings = check_source(PLAIN_PATH, src);
        assert_eq!(rules_of(&findings), [RULE_LOCK]);
        assert_eq!(findings[0].line, 2);
    }

    #[test]
    fn lock_unwrap_split_across_lines_is_still_found() {
        let src = "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock()\n        .unwrap()\n}\n";
        let findings = check_source(PLAIN_PATH, src);
        assert_eq!(rules_of(&findings), [RULE_LOCK]);
        assert_eq!(findings[0].line, 2, "span anchors on the .lock() line");
    }

    #[test]
    fn lock_unwrap_inside_cfg_test_is_allowed() {
        let src = "pub fn ok() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let m = std::sync::Mutex::new(1u8);\n        assert_eq!(*m.lock().unwrap(), 1);\n    }\n}\n";
        assert!(check_source(PLAIN_PATH, src).is_empty());
    }

    #[test]
    fn lock_unwrap_or_else_recovery_is_allowed() {
        let src = "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap_or_else(|p| p.into_inner())\n}\n";
        assert!(check_source(PLAIN_PATH, src).is_empty());
    }

    #[test]
    fn lock_unwrap_outside_src_is_not_this_rules_business() {
        let src = "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n";
        assert!(check_source("rust/tests/serve_integration.rs", src).is_empty());
    }

    #[test]
    fn banned_macros_each_produce_one_finding() {
        for mac in ["todo!()", "unimplemented!()", "dbg!(x)"] {
            let src = format!("fn f(x: u8) -> u8 {{\n    {mac}\n}}\n");
            let findings = check_source(PLAIN_PATH, &src);
            assert_eq!(rules_of(&findings), [RULE_BANNED], "{mac}");
            assert_eq!(findings[0].line, 2);
        }
    }

    #[test]
    fn clippy_deny_missing_on_a_module_is_flagged() {
        let src = "#[deny(clippy::all)]\npub mod good;\npub mod bad;\n";
        let findings = check_source("rust/src/lib.rs", src);
        assert_eq!(rules_of(&findings), [RULE_CLIPPY]);
        assert_eq!(findings[0].line, 3);
    }

    #[test]
    fn clippy_exempt_note_clears_the_finding() {
        let src = "// clippy-exempt: generated code, lints waived upstream.\npub mod generated;\n";
        assert!(check_source("rust/src/lib.rs", src).is_empty());
    }

    #[test]
    fn registration_flags_an_unregistered_test_file() {
        let cargo = "[package]\nautotests = false\nautobenches = false\n\n[[test]]\nname = \"a\"\npath = \"rust/tests/a.rs\"\n";
        let tests = ["a.rs".to_string(), "orphan.rs".to_string()];
        let findings = check_registration(cargo, &tests, &[]);
        assert_eq!(rules_of(&findings), [RULE_REGISTERED]);
        assert_eq!(findings[0].path, "rust/tests/orphan.rs");
    }

    #[test]
    fn registration_requires_autodiscovery_off() {
        let findings = check_registration("[package]\n", &[], &[]);
        assert_eq!(rules_of(&findings), [RULE_REGISTERED, RULE_REGISTERED]);
    }

    #[test]
    fn registration_accepts_a_fully_registered_layout() {
        let cargo = "autotests = false\nautobenches = false\n[[test]]\npath = \"rust/tests/a.rs\"\n[[bench]]\npath = \"rust/benches/b.rs\"\n";
        let tests = ["a.rs".to_string()];
        let benches = ["b.rs".to_string()];
        assert!(check_registration(cargo, &tests, &benches).is_empty());
    }
}
