//! A lightweight Rust surface lexer for the audit rules.
//!
//! The rules in [`super::rules`] scan for *tokens* (`unsafe`,
//! `.lock().unwrap()`, `todo!`) and must never fire on text inside string
//! literals or comments — `let s = "unsafe";` is not an unsafe block. A
//! full parser is overkill (and no parser crate is available offline), so
//! this lexer does exactly one job: split every line of a source file
//! into its **code** text and its **comment** text, with the contents of
//! string/char literals blanked out of the code channel.
//!
//! Handled syntax:
//!
//! * line comments `//`, doc comments `///` and `//!`;
//! * block comments `/* ... */`, including nesting and doc forms;
//! * string literals with escapes (`"a\"b"`), byte strings (`b"..."`);
//! * raw strings `r"..."`, `r#"..."#` (any hash depth), `br#"..."#`;
//! * char and byte-char literals (`'x'`, `'\n'`, `b'\0'`) versus
//!   lifetimes and labels (`'a`, `'static`, `'outer:`), disambiguated by
//!   lookahead.
//!
//! The output preserves line structure: `code[i]` and `comment[i]` are
//! the two channels of input line `i`, with literal contents replaced by
//! spaces (delimiters kept) so column positions stay meaningful.

/// A source file split into per-line code and comment channels.
#[derive(Debug, Clone)]
pub struct Lexed {
    /// Source line with comments removed and literal contents blanked.
    pub code: Vec<String>,
    /// Comment text found on the line (empty when none).
    pub comment: Vec<String>,
}

impl Lexed {
    /// Number of lines in the file.
    pub fn len(&self) -> usize {
        self.code.len()
    }

    /// True for a zero-line file.
    pub fn is_empty(&self) -> bool {
        self.code.is_empty()
    }

    /// The code channel joined back into one string (newline-separated) —
    /// what multi-line token scans operate on.
    pub fn code_text(&self) -> String {
        self.code.join("\n")
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    LineComment,
    /// Block comment with its nesting depth.
    BlockComment(u32),
    /// Regular string literal (escapes active).
    Str,
    /// Raw string terminated by `"` followed by this many `#`s.
    RawStr(u32),
    /// Char literal (escapes active).
    CharLit,
}

/// Split `src` into per-line code and comment channels.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut cur_code = String::new();
    let mut cur_comment = String::new();
    let mut state = State::Code;
    let mut i = 0usize;
    let n = chars.len();

    macro_rules! newline {
        () => {{
            code.push(std::mem::take(&mut cur_code));
            comment.push(std::mem::take(&mut cur_comment));
        }};
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            // A newline ends line comments; every other state carries over.
            if state == State::LineComment {
                state = State::Code;
            }
            newline!();
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    cur_code.push('"');
                    i += 1;
                } else if let Some(hashes) = raw_string_at(&chars, i) {
                    // `r`/`br` + hashes + the opening quote stay in code.
                    let intro = i..=(i + raw_intro_len(&chars, i, hashes));
                    for k in intro {
                        cur_code.push(chars[k]);
                    }
                    i += raw_intro_len(&chars, i, hashes) + 1;
                    state = State::RawStr(hashes);
                } else if c == '\'' && char_literal_at(&chars, i) {
                    state = State::CharLit;
                    cur_code.push('\'');
                    i += 1;
                } else {
                    cur_code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur_comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth > 1 { State::BlockComment(depth - 1) } else { State::Code };
                    if state == State::Code {
                        cur_code.push_str("  ");
                    }
                    i += 2;
                } else {
                    cur_comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    if chars.get(i + 1) == Some(&'\n') {
                        // Line continuation: keep the newline for the top of
                        // the loop so line numbering stays aligned.
                        cur_code.push(' ');
                        i += 1;
                    } else {
                        cur_code.push_str("  ");
                        i += 2; // skip the escaped char (may be `"` or `\`)
                    }
                } else if c == '"' {
                    cur_code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    cur_code.push('"');
                    for _ in 0..hashes {
                        cur_code.push('#');
                    }
                    i += 1 + hashes as usize;
                    state = State::Code;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    cur_code.push('\'');
                    state = State::Code;
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
        }
    }
    newline!();
    Lexed { code, comment }
}

/// Is the `'` at `chars[i]` a char literal (vs a lifetime/label)?
///
/// Char literal iff the quote is followed by an escape, or by exactly one
/// character and a closing quote. `'a` (no closing quote after one char)
/// is a lifetime.
fn char_literal_at(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(_) => chars.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// If an `r"`/`r#"`/`br#"` raw-string intro starts at `chars[i]`, return
/// its hash count; `None` otherwise. `i` must not be mid-identifier
/// (callers guarantee this implicitly: mid-identifier positions were
/// consumed char-by-char, and `var"` is not valid Rust anyway).
fn raw_string_at(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    // Reject identifier continuations like `for r in ..` → `r` followed by
    // a space is not a raw string; require hashes-then-quote immediately.
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        // Also make sure `chars[i]` starts a token: the previous char must
        // not be part of an identifier (e.g. `attr"` inside a macro).
        if i > 0 {
            let p = chars[i - 1];
            if p.is_alphanumeric() || p == '_' {
                return None;
            }
        }
        Some(hashes)
    } else {
        None
    }
}

/// Offset from `i` to the opening quote of a raw-string intro: the
/// optional `b`, the `r`, and the hashes.
fn raw_intro_len(chars: &[char], i: usize, hashes: u32) -> usize {
    usize::from(chars.get(i) == Some(&'b')) + 1 + hashes as usize
}

/// Does the `"` at `chars[i]` close a raw string expecting `hashes` `#`s?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_split_into_the_comment_channel() {
        let l = lex("let a = 1; // SAFETY: fine\nlet b = 2;");
        assert_eq!(l.code[0], "let a = 1; ");
        assert_eq!(l.comment[0], " SAFETY: fine");
        assert_eq!(l.code[1], "let b = 2;");
        assert_eq!(l.comment[1], "");
    }

    #[test]
    fn string_contents_are_blanked_but_delimiters_kept() {
        let l = lex(r#"let s = "unsafe { lock().unwrap() }";"#);
        assert!(!l.code[0].contains("unsafe"));
        assert!(!l.code[0].contains("unwrap"));
        assert!(l.code[0].starts_with("let s = \""));
        assert!(l.code[0].ends_with("\";"));
    }

    #[test]
    fn escaped_quotes_do_not_end_the_string() {
        let l = lex(r#"let s = "a\"unsafe\"b"; let t = 1;"#);
        assert!(!l.code[0].contains("unsafe"));
        assert!(l.code[0].contains("let t = 1;"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let l = lex("let s = r#\"unsafe \"quoted\" todo!\"#; let u = 2;");
        assert!(!l.code[0].contains("unsafe"));
        assert!(!l.code[0].contains("todo!"));
        assert!(l.code[0].contains("let u = 2;"));
    }

    #[test]
    fn byte_and_plain_raw_strings() {
        let l = lex(r#"let a = br"unsafe"; let b = r"dbg!"; let c = 3;"#);
        assert!(!l.code[0].contains("unsafe"));
        assert!(!l.code[0].contains("dbg!"));
        assert!(l.code[0].contains("let c = 3;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let l = lex("a /* one /* two */ still */ b\nc /* open\nunsafe\n*/ d");
        assert_eq!(l.code[0].split_whitespace().collect::<Vec<_>>(), ["a", "b"]);
        assert!(l.comment[0].contains("one"));
        assert!(!l.code[2].contains("unsafe"));
        assert!(l.comment[2].contains("unsafe"));
        assert!(l.code[3].contains('d'));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src =
            "fn f<'a>(x: &'a str) -> MutexGuard<'static, u8> { 'outer: loop { break 'outer; } }";
        let l = lex(src);
        // Everything stays in the code channel; nothing is swallowed as a
        // string-like literal.
        assert!(l.code[0].contains("'a str"));
        assert!(l.code[0].contains("'static"));
        assert!(l.code[0].contains("'outer: loop"));
    }

    #[test]
    fn char_literals_are_blanked() {
        let l = lex("let c = 'u'; let d = '\\''; let e = '\\n'; let f = 9;");
        assert!(l.code[0].contains("let f = 9;"));
        // the literal contents are gone, the quotes remain
        assert!(!l.code[0].contains("'u'"));
        assert!(l.code[0].contains('\''));
    }

    #[test]
    fn doc_comments_are_comments() {
        let l = lex("/// # Safety\n//! module doc\npub fn x() {}");
        assert_eq!(l.code[0].trim(), "");
        assert!(l.comment[0].contains("# Safety"));
        assert!(l.comment[1].contains("module doc"));
        assert_eq!(l.code[2], "pub fn x() {}");
    }

    #[test]
    fn multiline_strings_keep_line_structure() {
        let l = lex("let s = \"line one\nunsafe two\";\nlet t = 1;");
        assert_eq!(l.len(), 3);
        assert!(!l.code[1].contains("unsafe"));
        assert!(l.code[2].contains("let t = 1;"));
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_numbers() {
        let l = lex("let s = \"a\\\nunsafe b\";\nlet t = 2;");
        assert_eq!(l.len(), 3);
        assert!(!l.code[1].contains("unsafe"));
        assert!(l.code[2].contains("let t = 2;"));
    }

    #[test]
    fn code_text_preserves_line_count() {
        let src = "a\nb\n\nc";
        let l = lex(src);
        assert_eq!(l.len(), 4);
        assert_eq!(l.code_text().matches('\n').count(), 3);
    }
}
