//! Log-bucketed latency histogram.
//!
//! [`LatencyHistogram`] records microsecond observations into
//! geometrically-spaced buckets: values below 16 µs get one bucket each,
//! and every power-of-two octave above that is split into 8 sub-buckets,
//! so a reported quantile is at most ~12.5 % above the true value while
//! the whole histogram stays a fixed 496 × u64 — cheap enough to keep one
//! per load-generator client and merge at the end. The serve loadgen (both
//! in-process and network mode) reports p50/p99/p999 from it, replacing
//! mean/max-only latency accounting that hides tail behaviour.
//!
//! This is a plain (non-atomic) accumulator: writers own their histogram
//! and [`LatencyHistogram::merge`] combines thread-local tallies, matching
//! the aggregation pattern already used by `serve::loadgen::LoadReport`.

/// Sub-bucket resolution: 2^3 = 8 sub-buckets per octave.
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// Bucket count covering the full u64 range (shift ≤ 60 ⇒ index < 496).
const N_BUCKETS: usize = 496;

/// Index of the bucket holding `v` (microseconds).
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < 2 * SUB {
        return v as usize;
    }
    // v ≥ 16 ⇒ top ≥ 4 ⇒ shift ≥ 1; (v >> shift) ∈ [SUB, 2·SUB).
    let top = 63 - v.leading_zeros();
    let shift = top - SUB_BITS;
    let index = (shift as u64 * SUB + (v >> shift)) as usize;
    index.min(N_BUCKETS - 1)
}

/// Largest value mapping into bucket `i` (inclusive upper bound).
#[inline]
fn bucket_upper(i: usize) -> u64 {
    if i < (2 * SUB) as usize {
        return i as u64;
    }
    let shift = (i as u64 / SUB) - 1;
    let mantissa = i as u64 - shift * SUB;
    // Widen before shifting: the last bucket (i = 495) has shift = 60 and
    // mantissa = 15, where `(16u64 << 60)` silently truncates to 0 and the
    // `- 1` underflows (panics in debug). In u128 the bound is 2^64 - 1,
    // which saturates to exactly `u64::MAX` — the true inclusive upper
    // bound of the final bucket.
    let upper = ((mantissa as u128 + 1) << shift) - 1;
    upper.min(u64::MAX as u128) as u64
}

/// Fixed-size log-bucketed histogram of microsecond latencies.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    total_micros: u64,
    max_micros: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0; N_BUCKETS], count: 0, total_micros: 0, max_micros: 0 }
    }

    /// Record one observation (microseconds).
    pub fn record_micros(&mut self, micros: u64) {
        self.counts[bucket_index(micros)] += 1;
        self.count += 1;
        self.total_micros = self.total_micros.saturating_add(micros);
        self.max_micros = self.max_micros.max(micros);
    }

    /// Fold another histogram's tallies into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.total_micros = self.total_micros.saturating_add(other.total_micros);
        self.max_micros = self.max_micros.max(other.max_micros);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_micros(&self) -> u64 {
        self.max_micros
    }

    pub fn mean_micros(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_micros as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` ∈ [0, 1]: the upper bound of the bucket
    /// holding the ⌈q·n⌉-th smallest observation (≤ ~12.5 % above the true
    /// order statistic), clamped to the recorded maximum so `quantile(1.0)`
    /// is exact. Returns 0 on an empty histogram.
    pub fn quantile_micros(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(i).min(self.max_micros);
            }
        }
        self.max_micros
    }

    pub fn p50_micros(&self) -> u64 {
        self.quantile_micros(0.50)
    }

    pub fn p99_micros(&self) -> u64 {
        self.quantile_micros(0.99)
    }

    pub fn p999_micros(&self) -> u64 {
        self.quantile_micros(0.999)
    }

    /// One-line `p50/p99/p999/max` summary for CLI and bench output.
    pub fn summary(&self) -> String {
        format!(
            "p50 {} us, p99 {} us, p999 {} us, max {} us",
            self.p50_micros(),
            self.p99_micros(),
            self.p999_micros(),
            self.max_micros
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_contiguous_and_monotonic() {
        // Every value maps into a bucket whose bounds contain it, and
        // bucket indices never decrease with the value — checked densely
        // below 2^11 and at sampled points in EVERY octave up to u64::MAX
        // (lower edge, edge+1, mid, top-1, top). Pre-fix, bucket_upper
        // overflowed for the final bucket (`16u64 << 60` → 0, then `0 - 1`
        // panics in debug), so the u64::MAX samples here fail without the
        // widening fix.
        let mut samples: Vec<u64> = (0u64..2048).collect();
        for e in 11..64u32 {
            let lo = 1u64 << e;
            let hi = if e == 63 { u64::MAX } else { (1u64 << (e + 1)) - 1 };
            samples.extend([lo, lo + 1, lo + (lo >> 1), hi - 1, hi]);
        }
        let mut last = 0usize;
        for v in samples {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS, "v={v} escaped the bucket range");
            assert!(i >= last, "index regressed at {v}");
            last = i;
            // Containment: v never exceeds its bucket's inclusive upper
            // bound, and strictly exceeds the previous bucket's.
            assert!(v <= bucket_upper(i), "v={v} above upper bound of bucket {i}");
            if i > 0 {
                assert!(v > bucket_upper(i - 1), "v={v} below bucket {i}");
            }
        }
        // The final bucket is exactly the saturation point: u64::MAX maps
        // into it and its upper bound is u64::MAX itself.
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_upper(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = LatencyHistogram::new();
        for v in [0u64, 1, 5, 15] {
            h.record_micros(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.quantile_micros(0.25), 0);
        assert_eq!(h.quantile_micros(0.5), 1);
        assert_eq!(h.quantile_micros(0.75), 5);
        assert_eq!(h.quantile_micros(1.0), 15);
        assert_eq!(h.max_micros(), 15);
    }

    #[test]
    fn quantile_error_is_bounded() {
        // Uniform 1..=100_000: each reported quantile must be within
        // +12.5 % of the true order statistic (and never below it).
        let mut h = LatencyHistogram::new();
        for v in 1..=100_000u64 {
            h.record_micros(v);
        }
        for (q, truth) in [(0.5, 50_000u64), (0.99, 99_000), (0.999, 99_900)] {
            let got = h.quantile_micros(q);
            assert!(got >= truth, "q={q}: {got} < {truth}");
            assert!((got as f64) <= truth as f64 * 1.125 + 1.0, "q={q}: {got} >> {truth}");
        }
    }

    #[test]
    fn merge_combines_tallies() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for v in [10u64, 20, 30] {
            a.record_micros(v);
        }
        for v in [1_000u64, 2_000] {
            b.record_micros(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.max_micros(), 2_000);
        assert!(a.mean_micros() > 0.0);
        // p50 of {10,20,30,1000,2000} sits in 30's bucket
        assert!(a.p50_micros() >= 30 && a.p50_micros() <= 34, "{}", a.p50_micros());
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_micros(0.5), 0);
        assert_eq!(h.mean_micros(), 0.0);
        assert!(h.summary().contains("p50 0 us"));
    }

    #[test]
    fn quantiles_never_decrease_with_q() {
        let mut h = LatencyHistogram::new();
        let mut x = 7u64;
        for _ in 0..5_000 {
            // cheap LCG spread over ~6 orders of magnitude
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            h.record_micros(x % 3_000_000);
        }
        let mut last = 0u64;
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let v = h.quantile_micros(q);
            assert!(v >= last, "quantile decreased at q={q}");
            last = v;
        }
        assert_eq!(h.quantile_micros(1.0), h.max_micros());
    }
}
