//! Lock-free operational counters for long-running subsystems (the serve
//! engine's per-shard telemetry). Relaxed atomics everywhere: counters are
//! monotonic and read via point-in-time snapshots, so no ordering is needed
//! beyond atomicity.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Streaming latency accumulator: count, total, and max in microseconds.
/// Mean is derived at snapshot time; the max uses a CAS loop so concurrent
/// recorders never lose a larger observation.
#[derive(Debug, Default)]
pub struct LatencyStat {
    count: AtomicU64,
    total_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl LatencyStat {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_micros(&self, micros: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_micros.fetch_add(micros, Ordering::Relaxed);
        // Relaxed CAS loop: max-tracking needs only atomicity — a lost race
        // re-reads the (monotonically growing) current max and retries, so
        // no larger observation is ever dropped; no other memory location
        // is published through this value.
        let mut seen = self.max_micros.load(Ordering::Relaxed);
        while micros > seen {
            match self.max_micros.compare_exchange_weak(
                seen,
                micros,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => seen = actual,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total_micros(&self) -> u64 {
        self.total_micros.load(Ordering::Relaxed)
    }

    pub fn max_micros(&self) -> u64 {
        self.max_micros.load(Ordering::Relaxed)
    }

    pub fn mean_micros(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_micros() as f64 / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn latency_tracks_mean_and_max() {
        let l = LatencyStat::new();
        assert_eq!(l.mean_micros(), 0.0);
        l.record_micros(10);
        l.record_micros(30);
        l.record_micros(20);
        assert_eq!(l.count(), 3);
        assert_eq!(l.total_micros(), 60);
        assert_eq!(l.max_micros(), 30);
        assert!((l.mean_micros() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_updates_are_not_lost() {
        // Miri interprets every atomic op; fewer iterations still exercise
        // the same CAS races while keeping the lane fast.
        let per_thread: u64 = if cfg!(miri) { 25 } else { 1000 };
        let c = Counter::new();
        let l = LatencyStat::new();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let c = &c;
                let l = &l;
                s.spawn(move || {
                    for i in 0..per_thread {
                        c.inc();
                        l.record_micros(t * per_thread + i);
                    }
                });
            }
        });
        assert_eq!(c.get(), 4 * per_thread);
        assert_eq!(l.count(), 4 * per_thread);
        assert_eq!(l.max_micros(), 4 * per_thread - 1);
    }
}
