//! Evaluation metrics + aggregation across seeds/folds, plus the atomic
//! operational counters ([`counters`]) that the serve engine publishes its
//! per-shard latency / throughput / hit-rate telemetry through, and the
//! log-bucketed [`LatencyHistogram`] the load generators report
//! p50/p99/p999 tail latency from.

pub mod counters;
pub mod histogram;

pub use counters::{Counter, LatencyStat};
pub use histogram::LatencyHistogram;

use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// Classification accuracy from logits (row-major `(n, k)`) and labels.
pub fn accuracy_from_logits(logits: &[f32], n: usize, k: usize, labels: &[u32]) -> f64 {
    assert!(labels.len() >= n);
    assert!(logits.len() >= n * k);
    if n == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits[i * k..(i + 1) * k];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == labels[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Confusion matrix `(k, k)` row = true class, col = predicted.
pub fn confusion(logits: &[f32], n: usize, k: usize, labels: &[u32]) -> Vec<usize> {
    let mut cm = vec![0usize; k * k];
    for i in 0..n {
        let row = &logits[i * k..(i + 1) * k];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        cm[labels[i] as usize * k + best] += 1;
    }
    cm
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// The paper's structured "sparsity score": % of all-zero columns.
pub fn sparsity_percent<T: Scalar>(w: &Matrix<T>, tol: T) -> f64 {
    crate::norms::column_sparsity(w, tol) * 100.0
}

/// Feature-selection quality: of the `top_k` features ranked by `score`,
/// how many are truly informative (precision@k).
pub fn precision_at_k(scores: &[f64], informative: &[usize], top_k: usize) -> f64 {
    if top_k == 0 {
        return 0.0;
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let hits = idx[..top_k.min(idx.len())]
        .iter()
        .filter(|i| informative.contains(i))
        .count();
    hits as f64 / top_k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_simple() {
        // logits: sample0 -> class1, sample1 -> class0
        let logits = [0.1f32, 0.9, 0.8, 0.2];
        assert_eq!(accuracy_from_logits(&logits, 2, 2, &[1, 0]), 1.0);
        assert_eq!(accuracy_from_logits(&logits, 2, 2, &[0, 0]), 0.5);
    }

    #[test]
    fn confusion_diagonal_when_perfect() {
        let logits = [0.9f32, 0.1, 0.1, 0.9];
        let cm = confusion(&logits, 2, 2, &[0, 1]);
        assert_eq!(cm, vec![1, 0, 0, 1]);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn sparsity_percent_counts_columns() {
        let mut w = Matrix::<f64>::zeros(3, 4);
        w.set(0, 1, 1.0);
        assert_eq!(sparsity_percent(&w, 0.0), 75.0);
    }

    #[test]
    fn precision_at_k_ranks() {
        let scores = [0.9, 0.1, 0.8, 0.05];
        // top-2 = {0, 2}; informative = {0, 3} -> precision 0.5
        assert_eq!(precision_at_k(&scores, &[0, 3], 2), 0.5);
        assert_eq!(precision_at_k(&scores, &[0, 2], 2), 1.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(accuracy_from_logits(&[], 0, 2, &[]), 0.0);
        assert_eq!(precision_at_k(&[], &[], 0), 0.0);
    }
}
