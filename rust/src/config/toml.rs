//! TOML-subset parser.
//!
//! Supported grammar (sufficient for this repo's configs):
//!
//! ```toml
//! top_key = 1.5
//! [section]
//! name = "string"          # comment
//! flag = true
//! etas = [0.1, 0.5, 1.0]
//! tags = ["a", "b"]
//! [section.sub]
//! n = 42
//! ```
//!
//! Keys are flattened to `section.sub.key` form.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            TomlValue::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Array(xs) => xs.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
    pub fn as_str_array(&self) -> Option<Vec<String>> {
        match self {
            TomlValue::Array(xs) => {
                xs.iter().map(|x| x.as_str().map(|s| s.to_string())).collect()
            }
            _ => None,
        }
    }
}

/// Flat key → value map with dotted section prefixes.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|v| v as usize).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Parse a TOML-subset document. Errors carry line numbers.
pub fn parse(text: &str) -> Result<TomlDoc, String> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", ln + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", ln + 1));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", ln + 1));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let parsed = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", ln + 1))?;
        doc.values.insert(full_key, parsed);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Array(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    s.parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse(
            r#"
            lr = 1e-3           # learning rate
            [train]
            epochs = 30
            name = "synth64"
            quick = false
            etas = [0.1, 0.5, 1.0]
            [train.inner]
            deep = 1
            "#,
        )
        .unwrap();
        assert_eq!(doc.f64_or("lr", 0.0), 1e-3);
        assert_eq!(doc.usize_or("train.epochs", 0), 30);
        assert_eq!(doc.str_or("train.name", ""), "synth64");
        assert!(!doc.bool_or("train.quick", true));
        assert_eq!(
            doc.get("train.etas").unwrap().as_f64_array().unwrap(),
            vec![0.1, 0.5, 1.0]
        );
        assert_eq!(doc.usize_or("train.inner.deep", 0), 1);
    }

    #[test]
    fn defaults_on_missing_keys() {
        let doc = parse("").unwrap();
        assert_eq!(doc.f64_or("x", 2.5), 2.5);
        assert_eq!(doc.str_or("y", "d"), "d");
    }

    #[test]
    fn comments_inside_strings_survive() {
        let doc = parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn error_reports_line_numbers() {
        let err = parse("ok = 1\nbroken line").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = parse("[unterminated").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = parse("a = 3\nb = 3.5\nc = 1e2").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(3)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Float(3.5)));
        assert_eq!(doc.get("c"), Some(&TomlValue::Float(100.0)));
        assert_eq!(doc.get("a").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("b").unwrap().as_i64(), None);
    }

    #[test]
    fn string_arrays() {
        let doc = parse(r#"tags = ["x", "y"]"#).unwrap();
        match doc.get("tags").unwrap() {
            TomlValue::Array(xs) => {
                assert_eq!(xs[0].as_str(), Some("x"));
                assert_eq!(xs[1].as_str(), Some("y"));
            }
            _ => panic!("not an array"),
        }
        assert_eq!(
            doc.get("tags").unwrap().as_str_array(),
            Some(vec!["x".to_string(), "y".to_string()])
        );
        // mixed / non-string arrays refuse the string view
        let doc = parse("nums = [1, 2]").unwrap();
        assert_eq!(doc.get("nums").unwrap().as_str_array(), None);
    }
}
