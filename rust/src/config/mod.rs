//! Configuration system.
//!
//! A TOML-subset parser ([`toml`]) plus typed schema structs ([`schema`])
//! with presets matching the paper's experiments. No serde offline — the
//! parser supports exactly what the configs need: `[section]` headers,
//! `key = value` with strings, numbers, booleans, and flat arrays.

pub mod schema;
pub mod toml;

pub use schema::{
    DatasetKind, HttpConfig, PersistConfig, ProjectionBackend, ProjectionConfig,
    ProjectionMethod, RunConfig, ServeConfig, TrainConfig,
};
pub use toml::{parse, TomlDoc, TomlValue};
