//! Typed configuration schema + presets for the paper's experiments and
//! the projection service engine.

use std::time::Duration;

use super::toml::TomlDoc;
use crate::projection::l1::L1Algorithm;
use crate::projection::multilevel::MultilevelSpec;
use crate::projection::ProjectionKind;

/// Which dataset substrate a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// `make_classification`, 64 informative (paper data-64).
    Synth64,
    /// `make_classification`, 16 informative (paper data-16).
    Synth16,
    /// HIF2-sim 779×10000 (paper §V.C.2).
    Hif2,
    /// Tiny smoke dataset (tests/CI).
    Tiny,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "synth64" | "data64" | "data-64" => Some(Self::Synth64),
            "synth16" | "data16" | "data-16" => Some(Self::Synth16),
            "hif2" | "hif2sim" => Some(Self::Hif2),
            "tiny" => Some(Self::Tiny),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Synth64 => "synth64",
            Self::Synth16 => "synth16",
            Self::Hif2 => "hif2",
            Self::Tiny => "tiny",
        }
    }

    /// The AOT preset (artifact family) this dataset trains on.
    pub fn preset(&self) -> &'static str {
        match self {
            Self::Synth64 | Self::Synth16 => "synth",
            Self::Hif2 => "hif2",
            Self::Tiny => "tiny",
        }
    }
}

/// Where the W1 projection executes during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionBackend {
    /// The AOT Pallas kernel artifact (`{preset}_project.hlo.txt`).
    Pallas,
    /// The native Rust implementation (`projection::*`).
    Native,
}

impl ProjectionBackend {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pallas" | "kernel" => Some(Self::Pallas),
            "native" | "rust" => Some(Self::Native),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Pallas => "pallas",
            Self::Native => "native",
        }
    }
}

/// Training configuration (one SAE run).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: DatasetKind,
    pub projection: ProjectionKind,
    pub backend: ProjectionBackend,
    pub l1_algorithm: L1Algorithm,
    /// Projection radius η (paper's sweep parameter).
    pub eta: f64,
    /// Epochs per double-descent phase.
    pub epochs_phase1: usize,
    pub epochs_phase2: usize,
    pub lr: f64,
    /// Reconstruction-loss weight α in eq. (28).
    pub alpha: f64,
    /// Apply the projection every `project_every` steps during phase 1.
    pub project_every: usize,
    pub test_fraction: f64,
    pub seed: u64,
    /// Use the lax.scan epoch artifact (one dispatch/epoch) when true.
    pub use_epoch_artifact: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Synth64,
            projection: ProjectionKind::BilevelL1Inf,
            backend: ProjectionBackend::Native,
            l1_algorithm: L1Algorithm::Condat,
            eta: 1.0,
            epochs_phase1: 20,
            epochs_phase2: 10,
            lr: 1e-3,
            alpha: 1.0,
            project_every: 1,
            test_fraction: 0.2,
            seed: 42,
            use_epoch_artifact: true,
        }
    }
}

impl TrainConfig {
    /// Build from a parsed TOML doc (`[train]` section), defaults elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let dataset = DatasetKind::parse(doc.str_or("train.dataset", d.dataset.name()))
            .ok_or("train.dataset: unknown dataset")?;
        let projection =
            ProjectionKind::parse(doc.str_or("train.projection", d.projection.name()))
                .ok_or("train.projection: unknown projection")?;
        let backend = ProjectionBackend::parse(doc.str_or("train.backend", d.backend.name()))
            .ok_or("train.backend: unknown backend")?;
        let l1_algorithm =
            L1Algorithm::parse(doc.str_or("train.l1_algorithm", d.l1_algorithm.name()))
                .ok_or("train.l1_algorithm: unknown algorithm")?;
        let cfg = Self {
            dataset,
            projection,
            backend,
            l1_algorithm,
            eta: doc.f64_or("train.eta", d.eta),
            epochs_phase1: doc.usize_or("train.epochs_phase1", d.epochs_phase1),
            epochs_phase2: doc.usize_or("train.epochs_phase2", d.epochs_phase2),
            lr: doc.f64_or("train.lr", d.lr),
            alpha: doc.f64_or("train.alpha", d.alpha),
            project_every: doc.usize_or("train.project_every", d.project_every),
            test_fraction: doc.f64_or("train.test_fraction", d.test_fraction),
            seed: doc.usize_or("train.seed", d.seed as usize) as u64,
            use_epoch_artifact: doc.bool_or("train.use_epoch_artifact", d.use_epoch_artifact),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.eta < 0.0 {
            return Err("eta must be non-negative".into());
        }
        if !(0.0..1.0).contains(&self.test_fraction) {
            return Err("test_fraction must be in [0, 1)".into());
        }
        if self.lr <= 0.0 {
            return Err("lr must be positive".into());
        }
        if self.project_every == 0 {
            return Err("project_every must be >= 1".into());
        }
        Ok(())
    }

    /// Stable 64-bit digest over every field that shapes the numerical
    /// trajectory of a run (floats by bit pattern, enums by name).
    /// Stamped into checkpoints so resume refuses a drifted
    /// configuration; the seed is deliberately excluded (it is stored —
    /// and checked — separately).
    pub fn digest(&self) -> u64 {
        let canon = format!(
            "v1|{}|{}|{}|{}|{:016x}|{}|{}|{:016x}|{:016x}|{}|{:016x}|{}",
            self.dataset.name(),
            self.projection.name(),
            self.backend.name(),
            self.l1_algorithm.name(),
            self.eta.to_bits(),
            self.epochs_phase1,
            self.epochs_phase2,
            self.lr.to_bits(),
            self.alpha.to_bits(),
            self.project_every,
            self.test_fraction.to_bits(),
            self.use_epoch_artifact,
        );
        crate::persist::fnv1a64(canon.as_bytes())
    }
}

/// Model-lifecycle configuration (`[persist]` TOML section): where the
/// trainer's rolling checkpoints land and how often they are written.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PersistConfig {
    /// Write a rolling checkpoint every this many completed epochs
    /// (0 disables checkpointing).
    pub checkpoint_every: usize,
    /// Directory for checkpoint files (created on demand).
    pub dir: String,
    /// Include the full dense parameters in exported model checkpoints
    /// (larger files; enables re-compaction and weight dumps offline).
    pub export_dense: bool,
}

impl Default for PersistConfig {
    fn default() -> Self {
        Self { checkpoint_every: 0, dir: "checkpoints".into(), export_dense: false }
    }
}

impl PersistConfig {
    /// Build from a parsed TOML doc (`[persist]` section), defaults
    /// elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let cfg = Self {
            checkpoint_every: doc.usize_or("persist.checkpoint_every", d.checkpoint_every),
            dir: doc.str_or("persist.dir", &d.dir).to_string(),
            export_dense: doc.bool_or("persist.export_dense", d.export_dense),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.dir.is_empty() {
            return Err("persist.dir must not be empty".into());
        }
        Ok(())
    }
}

/// What the standalone `project` path applies: a flat [`ProjectionKind`]
/// or a [`MultilevelSpec`] projection tree.
#[derive(Clone, Debug, PartialEq)]
pub enum ProjectionMethod {
    Kind(ProjectionKind),
    Multilevel(MultilevelSpec),
}

impl ProjectionMethod {
    /// Resolve a method name plus an optional tree spec. `"multilevel"`
    /// requires `levels`; any other name must be a [`ProjectionKind`]
    /// (`levels`, if also given, is rejected to avoid silent ambiguity).
    pub fn parse(method: &str, levels: Option<&str>) -> Result<Self, String> {
        if method.eq_ignore_ascii_case("multilevel") {
            let spec = levels.ok_or(
                "projection method \"multilevel\" needs a tree spec \
                 (projection.levels / --levels), e.g. \"l1/l2:8/linf\"",
            )?;
            return Ok(Self::Multilevel(MultilevelSpec::parse(spec)?));
        }
        if levels.is_some() {
            return Err(format!(
                "projection.levels only applies to method \"multilevel\", not {method:?}"
            ));
        }
        ProjectionKind::parse(method)
            .map(Self::Kind)
            .ok_or_else(|| format!("unknown projection method {method:?}"))
    }

    /// Human-readable identifier (CSV headers, CLI echo).
    pub fn label(&self) -> String {
        match self {
            Self::Kind(k) => k.name().to_string(),
            Self::Multilevel(spec) => format!("multilevel({})", spec.format()),
        }
    }
}

/// Standalone projection operator configuration (`[projection]` TOML
/// section): what `bilevel project --config` applies and the defaults the
/// projection-family experiments run with.
#[derive(Clone, Debug, PartialEq)]
pub struct ProjectionConfig {
    pub method: ProjectionMethod,
    /// Projection radius η.
    pub eta: f64,
    /// Inner ℓ1 solver for the bi-level / ℓ2,1 / multilevel methods.
    pub algo: L1Algorithm,
    /// Parallel split cap for the multilevel tree (0 ⇒ hardware threads).
    pub threads: usize,
}

impl Default for ProjectionConfig {
    fn default() -> Self {
        Self {
            method: ProjectionMethod::Kind(ProjectionKind::BilevelL1Inf),
            eta: 1.0,
            algo: L1Algorithm::Condat,
            threads: 0,
        }
    }
}

impl ProjectionConfig {
    /// Build from a parsed TOML doc (`[projection]` section), defaults
    /// elsewhere. Keys: `method`, `levels` (multilevel tree spec string),
    /// `eta`, `algo`, `threads`.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let method_s = doc.str_or("projection.method", "bilevel-l1inf");
        let levels = doc.get("projection.levels").and_then(|v| v.as_str());
        let algo = L1Algorithm::parse(doc.str_or("projection.algo", d.algo.name()))
            .ok_or("projection.algo: unknown algorithm")?;
        let cfg = Self {
            method: ProjectionMethod::parse(method_s, levels)?,
            eta: doc.f64_or("projection.eta", d.eta),
            algo,
            threads: doc.usize_or("projection.threads", d.threads),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.eta.is_finite() || self.eta < 0.0 {
            return Err("projection.eta must be finite and non-negative".into());
        }
        Ok(())
    }
}

/// Configuration of the projection service engine (`serve` subsystem): a
/// sharded worker pool with bounded queues, a micro-batching scheduler, and
/// an LRU threshold cache. Parsed from the `[serve]` TOML section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Worker shards (0 ⇒ one per hardware thread).
    pub shards: usize,
    /// Worker threads consuming each shard's queue.
    pub workers_per_shard: usize,
    /// Bounded queue depth per shard — the backpressure high-water mark:
    /// submissions beyond it are rejected with a retry-after hint.
    pub queue_capacity: usize,
    /// Coalesce up to this many same-key (kind/shape/dtype/algo) requests
    /// into one scheduled batch. 1 disables batching.
    pub max_batch: usize,
    /// A worker keeps waiting (up to `max_wait_micros`) until a batch holds
    /// this many requests. 1 = opportunistic batching: coalesce whatever is
    /// already queued, never idle-wait.
    pub min_fill: usize,
    /// Batch-fill wait budget (only reached when `min_fill > 1`).
    pub max_wait_micros: u64,
    /// LRU threshold-cache entries shared by all shards (0 disables).
    pub cache_capacity: usize,
    /// Consecutive encode execution failures that trip a model's circuit
    /// breaker open.
    pub breaker_threshold: usize,
    /// How long a tripped breaker refuses a model's traffic before
    /// admitting a half-open probe.
    pub breaker_cooldown_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            shards: 0,
            workers_per_shard: 1,
            queue_capacity: 64,
            max_batch: 8,
            min_fill: 1,
            max_wait_micros: 200,
            cache_capacity: 256,
            breaker_threshold: 5,
            breaker_cooldown_ms: 1_000,
        }
    }
}

impl ServeConfig {
    /// Resolve `shards = 0` to the hardware parallelism.
    pub fn effective_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// `max_wait_micros` as a `Duration`.
    pub fn max_wait(&self) -> Duration {
        Duration::from_micros(self.max_wait_micros)
    }

    /// Build from a parsed TOML doc (`[serve]` section), defaults elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let cfg = Self {
            shards: doc.usize_or("serve.shards", d.shards),
            workers_per_shard: doc.usize_or("serve.workers_per_shard", d.workers_per_shard),
            queue_capacity: doc.usize_or("serve.queue_capacity", d.queue_capacity),
            max_batch: doc.usize_or("serve.max_batch", d.max_batch),
            min_fill: doc.usize_or("serve.min_fill", d.min_fill),
            max_wait_micros: doc.usize_or("serve.max_wait_micros", d.max_wait_micros as usize)
                as u64,
            cache_capacity: doc.usize_or("serve.cache_capacity", d.cache_capacity),
            breaker_threshold: doc.usize_or("serve.breaker_threshold", d.breaker_threshold),
            breaker_cooldown_ms: doc
                .usize_or("serve.breaker_cooldown_ms", d.breaker_cooldown_ms as usize)
                as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers_per_shard == 0 {
            return Err("serve.workers_per_shard must be >= 1".into());
        }
        if self.queue_capacity == 0 {
            return Err("serve.queue_capacity must be >= 1".into());
        }
        if self.max_batch == 0 {
            return Err("serve.max_batch must be >= 1".into());
        }
        if self.min_fill == 0 || self.min_fill > self.max_batch {
            return Err("serve.min_fill must be in 1..=serve.max_batch".into());
        }
        if self.breaker_threshold == 0 {
            return Err("serve.breaker_threshold must be >= 1".into());
        }
        if self.breaker_threshold > u32::MAX as usize {
            return Err("serve.breaker_threshold is out of range".into());
        }
        if self.breaker_cooldown_ms == 0 {
            return Err("serve.breaker_cooldown_ms must be >= 1".into());
        }
        Ok(())
    }
}

/// HTTP front-end configuration (`[serve.http]` TOML section): the
/// listener, connection/parse hardening limits, per-client quotas, and the
/// SSE snapshot cadence. Consumed by `net::Server`.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpConfig {
    /// Listen address, numeric `ip:port` (`:0` lets the OS pick the port).
    pub listen: String,
    /// Concurrent connections beyond this are refused with 503.
    pub max_connections: usize,
    /// Socket read timeout — a stalled peer is timed out (408) after this.
    pub read_timeout_ms: u64,
    /// Socket write timeout — a peer that stops reading its response is
    /// timed out (connection closed, counted in the net report) after this.
    pub write_timeout_ms: u64,
    /// Request-body cap (413 beyond it).
    pub max_body_bytes: usize,
    /// Header-section cap (431 beyond it).
    pub max_header_bytes: usize,
    /// Sustained per-client requests/second; 0 disables quota admission.
    pub quota_rps: f64,
    /// Token-bucket burst capacity (only read when `quota_rps > 0`).
    pub quota_burst: f64,
    /// Interval between SSE stats snapshots on `GET /v1/events`.
    pub sse_interval_ms: u64,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:8080".into(),
            max_connections: 256,
            read_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            max_body_bytes: 16 * 1024 * 1024,
            max_header_bytes: 16 * 1024,
            quota_rps: 0.0,
            quota_burst: 8.0,
            sse_interval_ms: 200,
        }
    }
}

impl HttpConfig {
    pub fn read_timeout(&self) -> Duration {
        Duration::from_millis(self.read_timeout_ms)
    }

    pub fn write_timeout(&self) -> Duration {
        Duration::from_millis(self.write_timeout_ms)
    }

    pub fn sse_interval(&self) -> Duration {
        Duration::from_millis(self.sse_interval_ms)
    }

    /// Build from a parsed TOML doc (`[serve.http]` section), defaults
    /// elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let cfg = Self {
            listen: doc.str_or("serve.http.listen", &d.listen).to_string(),
            max_connections: doc.usize_or("serve.http.max_connections", d.max_connections),
            read_timeout_ms: doc
                .usize_or("serve.http.read_timeout_ms", d.read_timeout_ms as usize)
                as u64,
            write_timeout_ms: doc
                .usize_or("serve.http.write_timeout_ms", d.write_timeout_ms as usize)
                as u64,
            max_body_bytes: doc.usize_or("serve.http.max_body_bytes", d.max_body_bytes),
            max_header_bytes: doc.usize_or("serve.http.max_header_bytes", d.max_header_bytes),
            quota_rps: doc.f64_or("serve.http.quota_rps", d.quota_rps),
            quota_burst: doc.f64_or("serve.http.quota_burst", d.quota_burst),
            sse_interval_ms: doc
                .usize_or("serve.http.sse_interval_ms", d.sse_interval_ms as usize)
                as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.listen.is_empty() {
            return Err("serve.http.listen must not be empty".into());
        }
        if self.max_connections == 0 {
            return Err("serve.http.max_connections must be >= 1".into());
        }
        if self.read_timeout_ms == 0 {
            return Err("serve.http.read_timeout_ms must be >= 1".into());
        }
        if self.write_timeout_ms == 0 {
            return Err("serve.http.write_timeout_ms must be >= 1".into());
        }
        if self.max_body_bytes == 0 {
            return Err("serve.http.max_body_bytes must be >= 1".into());
        }
        if self.max_header_bytes < 256 {
            return Err("serve.http.max_header_bytes must be >= 256".into());
        }
        if !self.quota_rps.is_finite() || self.quota_rps < 0.0 {
            return Err("serve.http.quota_rps must be finite and >= 0".into());
        }
        if self.quota_rps > 0.0 && (!self.quota_burst.is_finite() || self.quota_burst < 1.0) {
            return Err("serve.http.quota_burst must be >= 1 when quotas are on".into());
        }
        if self.sse_interval_ms == 0 {
            return Err("serve.http.sse_interval_ms must be >= 1".into());
        }
        Ok(())
    }
}

/// Top-level run configuration (CLI entry).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub train: TrainConfig,
    pub serve: ServeConfig,
    pub persist: PersistConfig,
    pub projection: ProjectionConfig,
    pub artifacts_dir: String,
    pub seeds: Vec<u64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            train: TrainConfig::default(),
            serve: ServeConfig::default(),
            persist: PersistConfig::default(),
            projection: ProjectionConfig::default(),
            artifacts_dir: "artifacts".into(),
            seeds: vec![42, 43, 44, 45],
        }
    }
}

impl RunConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let seeds = match doc.get("run.seeds") {
            Some(v) => v
                .as_f64_array()
                .ok_or("run.seeds must be an array of integers")?
                .iter()
                .map(|&x| x as u64)
                .collect(),
            None => d.seeds,
        };
        Ok(Self {
            train: TrainConfig::from_doc(doc)?,
            serve: ServeConfig::from_doc(doc)?,
            persist: PersistConfig::from_doc(doc)?,
            projection: ProjectionConfig::from_doc(doc)?,
            artifacts_dir: doc.str_or("run.artifacts_dir", &d.artifacts_dir).to_string(),
            seeds,
        })
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_doc(&super::toml::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml::parse;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn from_doc_overrides() {
        let doc = parse(
            r#"
            [train]
            dataset = "hif2"
            projection = "l1inf-ssn"
            backend = "pallas"
            eta = 0.25
            epochs_phase1 = 5
            [run]
            seeds = [1, 2, 3]
            artifacts_dir = "arts"
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.train.dataset, DatasetKind::Hif2);
        assert_eq!(cfg.train.projection, ProjectionKind::ExactL1InfSsn);
        assert_eq!(cfg.train.backend, ProjectionBackend::Pallas);
        assert_eq!(cfg.train.eta, 0.25);
        assert_eq!(cfg.train.epochs_phase1, 5);
        assert_eq!(cfg.seeds, vec![1, 2, 3]);
        assert_eq!(cfg.artifacts_dir, "arts");
    }

    #[test]
    fn invalid_values_rejected() {
        let doc = parse("[train]\neta = -1.0").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = parse("[train]\ndataset = \"bogus\"").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = parse("[train]\nproject_every = 0").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn shipped_config_files_parse() {
        for f in ["configs/synth64.toml", "configs/hif2.toml", "configs/baseline.toml"] {
            let cfg = RunConfig::from_file(f).unwrap_or_else(|e| panic!("{f}: {e}"));
            cfg.train.validate().unwrap();
        }
        // and they differ meaningfully
        let a = RunConfig::from_file("configs/synth64.toml").unwrap();
        let b = RunConfig::from_file("configs/hif2.toml").unwrap();
        assert_eq!(a.train.dataset, DatasetKind::Synth64);
        assert_eq!(b.train.dataset, DatasetKind::Hif2);
        assert_eq!(a.train.backend, ProjectionBackend::Pallas);
    }

    #[test]
    fn serve_defaults_validate_and_parse() {
        ServeConfig::default().validate().unwrap();
        assert!(ServeConfig::default().effective_shards() >= 1);
        let doc = parse(
            r#"
            [serve]
            shards = 4
            queue_capacity = 16
            max_batch = 32
            min_fill = 32
            max_wait_micros = 1000
            cache_capacity = 0
            "#,
        )
        .unwrap();
        let cfg = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.effective_shards(), 4);
        assert_eq!(cfg.queue_capacity, 16);
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.min_fill, 32);
        assert_eq!(cfg.max_wait(), std::time::Duration::from_millis(1));
        assert_eq!(cfg.cache_capacity, 0);
        // defaults fill the gaps
        assert_eq!(cfg.workers_per_shard, 1);
        assert_eq!(cfg.breaker_threshold, 5);
        assert_eq!(cfg.breaker_cooldown_ms, 1_000);
        let doc = parse("[serve]\nbreaker_threshold = 2\nbreaker_cooldown_ms = 75").unwrap();
        let cfg = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!((cfg.breaker_threshold, cfg.breaker_cooldown_ms), (2, 75));
    }

    #[test]
    fn serve_invalid_values_rejected() {
        let doc = parse("[serve]\nqueue_capacity = 0").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
        let doc = parse("[serve]\nmax_batch = 0").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
        let doc = parse("[serve]\nmax_batch = 4\nmin_fill = 5").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
        let doc = parse("[serve]\nworkers_per_shard = 0").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
        let doc = parse("[serve]\nbreaker_threshold = 0").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
        let doc = parse("[serve]\nbreaker_cooldown_ms = 0").unwrap();
        assert!(ServeConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn run_config_includes_serve_section() {
        let doc = parse("[serve]\nshards = 2\nmax_batch = 4").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.serve.shards, 2);
        assert_eq!(cfg.serve.max_batch, 4);
        assert_eq!(RunConfig::default().serve, ServeConfig::default());
    }

    #[test]
    fn persist_section_parses_with_defaults() {
        let d = PersistConfig::default();
        assert_eq!(d.checkpoint_every, 0);
        d.validate().unwrap();
        let doc = parse("[persist]\ncheckpoint_every = 5\ndir = \"ckpts\"\nexport_dense = true")
            .unwrap();
        let cfg = PersistConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.checkpoint_every, 5);
        assert_eq!(cfg.dir, "ckpts");
        assert!(cfg.export_dense);
        let doc = parse("[persist]\ndir = \"\"").unwrap();
        assert!(PersistConfig::from_doc(&doc).is_err());
        // RunConfig carries the section
        let doc = parse("[persist]\ncheckpoint_every = 3").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().persist.checkpoint_every, 3);
        assert_eq!(RunConfig::default().persist, PersistConfig::default());
    }

    #[test]
    fn projection_section_parses_flat_and_multilevel() {
        ProjectionConfig::default().validate().unwrap();
        let doc = parse("[projection]\nmethod = \"l21\"\neta = 0.75\nalgo = \"michelot\"")
            .unwrap();
        let cfg = ProjectionConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.method, ProjectionMethod::Kind(ProjectionKind::L21));
        assert_eq!(cfg.eta, 0.75);
        assert_eq!(cfg.algo, L1Algorithm::Michelot);

        let doc = parse(
            "[projection]\nmethod = \"multilevel\"\nlevels = \"l1/l2:8/linf\"\nthreads = 3",
        )
        .unwrap();
        let cfg = ProjectionConfig::from_doc(&doc).unwrap();
        match &cfg.method {
            ProjectionMethod::Multilevel(spec) => assert_eq!(spec.format(), "l1/l2:8/linf"),
            other => panic!("expected multilevel, got {other:?}"),
        }
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.method.label(), "multilevel(l1/l2:8/linf)");

        // RunConfig carries the section; an empty doc falls back to defaults.
        let doc = parse("[projection]\nmethod = \"linf1-newton\"").unwrap();
        let run = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(run.projection.method, ProjectionMethod::Kind(ProjectionKind::Linf1Newton));
        assert_eq!(RunConfig::default().projection, ProjectionConfig::default());
    }

    #[test]
    fn projection_section_invalid_values_rejected() {
        for bad in [
            "[projection]\nmethod = \"bogus\"",
            "[projection]\nmethod = \"multilevel\"",           // missing levels
            "[projection]\nmethod = \"multilevel\"\nlevels = \"l1\"", // depth 1
            "[projection]\nmethod = \"l21\"\nlevels = \"l1/linf\"",   // levels without multilevel
            "[projection]\neta = -2.0",
            "[projection]\nalgo = \"bogus\"",
        ] {
            let doc = parse(bad).unwrap();
            assert!(ProjectionConfig::from_doc(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn http_section_parses_with_defaults() {
        let d = HttpConfig::default();
        d.validate().unwrap();
        assert_eq!(d.listen, "127.0.0.1:8080");
        assert_eq!(d.quota_rps, 0.0, "quotas default off");
        let doc = parse(
            r#"
            [serve.http]
            listen = "127.0.0.1:0"
            max_connections = 32
            read_timeout_ms = 250
            write_timeout_ms = 300
            max_body_bytes = 1048576
            quota_rps = 50.0
            quota_burst = 10.0
            sse_interval_ms = 25
            "#,
        )
        .unwrap();
        let cfg = HttpConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.listen, "127.0.0.1:0");
        assert_eq!(cfg.max_connections, 32);
        assert_eq!(cfg.read_timeout(), std::time::Duration::from_millis(250));
        assert_eq!(cfg.write_timeout(), std::time::Duration::from_millis(300));
        assert_eq!(cfg.max_body_bytes, 1 << 20);
        assert_eq!(cfg.quota_rps, 50.0);
        assert_eq!(cfg.sse_interval(), std::time::Duration::from_millis(25));
        // defaults fill the gaps
        assert_eq!(cfg.max_header_bytes, HttpConfig::default().max_header_bytes);
    }

    #[test]
    fn http_invalid_values_rejected() {
        for bad in [
            "[serve.http]\nlisten = \"\"",
            "[serve.http]\nmax_connections = 0",
            "[serve.http]\nread_timeout_ms = 0",
            "[serve.http]\nwrite_timeout_ms = 0",
            "[serve.http]\nmax_body_bytes = 0",
            "[serve.http]\nmax_header_bytes = 10",
            "[serve.http]\nquota_rps = -1.0",
            "[serve.http]\nquota_rps = 5.0\nquota_burst = 0.5",
            "[serve.http]\nsse_interval_ms = 0",
        ] {
            let doc = parse(bad).unwrap();
            assert!(HttpConfig::from_doc(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn serve_http_config_file_parses() {
        let text = std::fs::read_to_string("configs/serve_http.toml").unwrap();
        let doc = parse(&text).unwrap();
        let http = HttpConfig::from_doc(&doc).unwrap();
        assert!(http.quota_rps > 0.0, "sample config must exercise quotas");
        // the file also carries coherent [serve] + [loadgen] sections
        let serve = ServeConfig::from_doc(&doc).unwrap();
        serve.validate().unwrap();
        assert!(doc.get("loadgen.clients").is_some());
    }

    #[test]
    fn chaos_config_file_parses_with_fault_plan() {
        let text = std::fs::read_to_string("configs/chaos.toml").unwrap();
        let doc = parse(&text).unwrap();
        ServeConfig::from_doc(&doc).unwrap().validate().unwrap();
        HttpConfig::from_doc(&doc).unwrap().validate().unwrap();
        crate::serve::LoadgenConfig::from_doc(&doc).unwrap().validate().unwrap();
        let plan = crate::fault::FaultPlan::from_doc(&doc)
            .unwrap()
            .expect("chaos config must arm at least one fault site");
        assert!(plan.site(crate::fault::FaultSite::WorkerPanic).is_some());
        assert!(plan.site(crate::fault::FaultSite::ConnReset).is_some());
    }

    #[test]
    fn train_digest_tracks_trajectory_fields_only() {
        let a = TrainConfig::default();
        assert_eq!(a.digest(), TrainConfig::default().digest(), "digest must be stable");
        let b = TrainConfig { eta: a.eta + 0.5, ..a.clone() };
        assert_ne!(a.digest(), b.digest());
        let c = TrainConfig { epochs_phase2: a.epochs_phase2 + 1, ..a.clone() };
        assert_ne!(a.digest(), c.digest());
        // the seed is checked separately, not part of the digest
        let d = TrainConfig { seed: a.seed + 1, ..a.clone() };
        assert_eq!(a.digest(), d.digest());
    }

    #[test]
    fn dataset_preset_mapping() {
        assert_eq!(DatasetKind::Synth64.preset(), "synth");
        assert_eq!(DatasetKind::Synth16.preset(), "synth");
        assert_eq!(DatasetKind::Hif2.preset(), "hif2");
        assert_eq!(DatasetKind::parse("data-64"), Some(DatasetKind::Synth64));
    }
}
