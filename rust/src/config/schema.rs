//! Typed configuration schema + presets for the paper's experiments.

use super::toml::TomlDoc;
use crate::projection::l1::L1Algorithm;
use crate::projection::ProjectionKind;

/// Which dataset substrate a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// `make_classification`, 64 informative (paper data-64).
    Synth64,
    /// `make_classification`, 16 informative (paper data-16).
    Synth16,
    /// HIF2-sim 779×10000 (paper §V.C.2).
    Hif2,
    /// Tiny smoke dataset (tests/CI).
    Tiny,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "synth64" | "data64" | "data-64" => Some(Self::Synth64),
            "synth16" | "data16" | "data-16" => Some(Self::Synth16),
            "hif2" | "hif2sim" => Some(Self::Hif2),
            "tiny" => Some(Self::Tiny),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Synth64 => "synth64",
            Self::Synth16 => "synth16",
            Self::Hif2 => "hif2",
            Self::Tiny => "tiny",
        }
    }

    /// The AOT preset (artifact family) this dataset trains on.
    pub fn preset(&self) -> &'static str {
        match self {
            Self::Synth64 | Self::Synth16 => "synth",
            Self::Hif2 => "hif2",
            Self::Tiny => "tiny",
        }
    }
}

/// Where the W1 projection executes during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionBackend {
    /// The AOT Pallas kernel artifact (`{preset}_project.hlo.txt`).
    Pallas,
    /// The native Rust implementation (`projection::*`).
    Native,
}

impl ProjectionBackend {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pallas" | "kernel" => Some(Self::Pallas),
            "native" | "rust" => Some(Self::Native),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Pallas => "pallas",
            Self::Native => "native",
        }
    }
}

/// Training configuration (one SAE run).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: DatasetKind,
    pub projection: ProjectionKind,
    pub backend: ProjectionBackend,
    pub l1_algorithm: L1Algorithm,
    /// Projection radius η (paper's sweep parameter).
    pub eta: f64,
    /// Epochs per double-descent phase.
    pub epochs_phase1: usize,
    pub epochs_phase2: usize,
    pub lr: f64,
    /// Reconstruction-loss weight α in eq. (28).
    pub alpha: f64,
    /// Apply the projection every `project_every` steps during phase 1.
    pub project_every: usize,
    pub test_fraction: f64,
    pub seed: u64,
    /// Use the lax.scan epoch artifact (one dispatch/epoch) when true.
    pub use_epoch_artifact: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Synth64,
            projection: ProjectionKind::BilevelL1Inf,
            backend: ProjectionBackend::Native,
            l1_algorithm: L1Algorithm::Condat,
            eta: 1.0,
            epochs_phase1: 20,
            epochs_phase2: 10,
            lr: 1e-3,
            alpha: 1.0,
            project_every: 1,
            test_fraction: 0.2,
            seed: 42,
            use_epoch_artifact: true,
        }
    }
}

impl TrainConfig {
    /// Build from a parsed TOML doc (`[train]` section), defaults elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let dataset = DatasetKind::parse(doc.str_or("train.dataset", d.dataset.name()))
            .ok_or("train.dataset: unknown dataset")?;
        let projection =
            ProjectionKind::parse(doc.str_or("train.projection", d.projection.name()))
                .ok_or("train.projection: unknown projection")?;
        let backend = ProjectionBackend::parse(doc.str_or("train.backend", d.backend.name()))
            .ok_or("train.backend: unknown backend")?;
        let l1_algorithm =
            L1Algorithm::parse(doc.str_or("train.l1_algorithm", d.l1_algorithm.name()))
                .ok_or("train.l1_algorithm: unknown algorithm")?;
        let cfg = Self {
            dataset,
            projection,
            backend,
            l1_algorithm,
            eta: doc.f64_or("train.eta", d.eta),
            epochs_phase1: doc.usize_or("train.epochs_phase1", d.epochs_phase1),
            epochs_phase2: doc.usize_or("train.epochs_phase2", d.epochs_phase2),
            lr: doc.f64_or("train.lr", d.lr),
            alpha: doc.f64_or("train.alpha", d.alpha),
            project_every: doc.usize_or("train.project_every", d.project_every),
            test_fraction: doc.f64_or("train.test_fraction", d.test_fraction),
            seed: doc.usize_or("train.seed", d.seed as usize) as u64,
            use_epoch_artifact: doc.bool_or("train.use_epoch_artifact", d.use_epoch_artifact),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.eta < 0.0 {
            return Err("eta must be non-negative".into());
        }
        if !(0.0..1.0).contains(&self.test_fraction) {
            return Err("test_fraction must be in [0, 1)".into());
        }
        if self.lr <= 0.0 {
            return Err("lr must be positive".into());
        }
        if self.project_every == 0 {
            return Err("project_every must be >= 1".into());
        }
        Ok(())
    }
}

/// Top-level run configuration (CLI entry).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub train: TrainConfig,
    pub artifacts_dir: String,
    pub seeds: Vec<u64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            train: TrainConfig::default(),
            artifacts_dir: "artifacts".into(),
            seeds: vec![42, 43, 44, 45],
        }
    }
}

impl RunConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let seeds = match doc.get("run.seeds") {
            Some(v) => v
                .as_f64_array()
                .ok_or("run.seeds must be an array of integers")?
                .iter()
                .map(|&x| x as u64)
                .collect(),
            None => d.seeds,
        };
        Ok(Self {
            train: TrainConfig::from_doc(doc)?,
            artifacts_dir: doc.str_or("run.artifacts_dir", &d.artifacts_dir).to_string(),
            seeds,
        })
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_doc(&super::toml::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml::parse;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn from_doc_overrides() {
        let doc = parse(
            r#"
            [train]
            dataset = "hif2"
            projection = "l1inf-ssn"
            backend = "pallas"
            eta = 0.25
            epochs_phase1 = 5
            [run]
            seeds = [1, 2, 3]
            artifacts_dir = "arts"
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.train.dataset, DatasetKind::Hif2);
        assert_eq!(cfg.train.projection, ProjectionKind::ExactL1InfSsn);
        assert_eq!(cfg.train.backend, ProjectionBackend::Pallas);
        assert_eq!(cfg.train.eta, 0.25);
        assert_eq!(cfg.train.epochs_phase1, 5);
        assert_eq!(cfg.seeds, vec![1, 2, 3]);
        assert_eq!(cfg.artifacts_dir, "arts");
    }

    #[test]
    fn invalid_values_rejected() {
        let doc = parse("[train]\neta = -1.0").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = parse("[train]\ndataset = \"bogus\"").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = parse("[train]\nproject_every = 0").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn shipped_config_files_parse() {
        for f in ["configs/synth64.toml", "configs/hif2.toml", "configs/baseline.toml"] {
            let cfg = RunConfig::from_file(f).unwrap_or_else(|e| panic!("{f}: {e}"));
            cfg.train.validate().unwrap();
        }
        // and they differ meaningfully
        let a = RunConfig::from_file("configs/synth64.toml").unwrap();
        let b = RunConfig::from_file("configs/hif2.toml").unwrap();
        assert_eq!(a.train.dataset, DatasetKind::Synth64);
        assert_eq!(b.train.dataset, DatasetKind::Hif2);
        assert_eq!(a.train.backend, ProjectionBackend::Pallas);
    }

    #[test]
    fn dataset_preset_mapping() {
        assert_eq!(DatasetKind::Synth64.preset(), "synth");
        assert_eq!(DatasetKind::Synth16.preset(), "synth");
        assert_eq!(DatasetKind::Hif2.preset(), "hif2");
        assert_eq!(DatasetKind::parse("data-64"), Some(DatasetKind::Synth64));
    }
}
