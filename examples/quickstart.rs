//! Quickstart: the projection library in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks through the paper's core objects: the bi-level ℓ1,∞ projection
//! (Algorithm 1), the exact projection it replaces, the norm identity
//! (Proposition III.3), and the structured-sparsity difference between the
//! two (Remark III.6) — no artifacts or Python required.

use bilevel_sparse::prelude::*;
use bilevel_sparse::projection::bilevel::{bilevel, bilevel_l1inf_with, BilevelVariant};
use bilevel_sparse::projection::l1inf::L1InfAlgorithm;
use bilevel_sparse::tensor::Matrix as M;

fn main() {
    // A random 200x100 matrix: 200 rows ("hidden units"), 100 columns
    // ("features"). The l1,inf ball couples columns: its projection can
    // zero whole columns at once.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let y = Matrix::<f64>::randn(200, 100, &mut rng);
    let eta = 5.0;

    println!("Y: {}x{} gaussian, ||Y||_1inf = {:.3}\n", y.rows(), y.cols(), l1inf_norm(&y));

    // --- 1. The paper's contribution: BP^{1,inf}, O(nm) -----------------
    let t0 = std::time::Instant::now();
    let bp = bilevel_l1inf(&y, eta);
    let t_bp = t0.elapsed();
    println!("BP^(1,inf) (Algorithm 1, O(nm)):");
    println!("  ||BP(Y)||_1inf   = {:.6}  (radius eta = {eta})", l1inf_norm(&bp));
    println!("  zero columns     = {} / {}", bp.zero_columns(0.0).len(), bp.cols());
    println!("  time             = {t_bp:?}");

    // --- 2. The exact projection it replaces (Chu et al. port) ----------
    let t0 = std::time::Instant::now();
    let p = project_l1inf(&y, eta, L1InfAlgorithm::Ssn);
    let t_p = t0.elapsed();
    println!("\nExact P^(1,inf) (semismooth Newton port):");
    println!("  ||P(Y)||_1inf    = {:.6}", l1inf_norm(&p));
    println!("  zero columns     = {} / {}", p.zero_columns(0.0).len(), p.cols());
    println!("  time             = {t_p:?}");

    // --- 3. The identity (Proposition III.3 / III.5) ---------------------
    println!("\nThe l1,inf identity ||Y - P(Y)|| + ||P(Y)|| = ||Y||:");
    for (name, x) in [("bilevel", &bp), ("exact  ", &p)] {
        let lhs = l1inf_norm(&y.sub(x)) + l1inf_norm(x);
        println!(
            "  {name}: {lhs:.9} = {:.9}  (gap {:.2e})",
            l1inf_norm(&y),
            (lhs - l1inf_norm(&y)).abs()
        );
    }

    // --- 4. The trade-off (Remark III.6) ---------------------------------
    let e_bp = frobenius_norm(&y.sub(&bp));
    let e_p = frobenius_norm(&y.sub(&p));
    println!("\nTrade-off: BP sparser, P closer in l2:");
    println!("  l2 error   bilevel {e_bp:.4}  vs exact {e_p:.4}");
    println!(
        "  sparsity   bilevel {:>3} cols vs exact {:>3} cols",
        bp.zero_columns(0.0).len(),
        p.zero_columns(0.0).len()
    );

    // --- 5. The other bi-level variants (Algorithms 2-3) ----------------
    type NormFn = fn(&M<f64>) -> f64;
    println!("\nBi-level variants at a matched 5% norm ratio:");
    let variants: [(BilevelVariant, NormFn); 3] = [
        (BilevelVariant::L1Inf, l1inf_norm::<f64>),
        (BilevelVariant::L11, l11_norm::<f64>),
        (BilevelVariant::L12, l12_norm::<f64>),
    ];
    for (variant, norm) in variants {
        let r = bilevel(&y, norm(&y) * 0.05, variant, L1Algorithm::Condat);
        println!(
            "  {:<14} zero columns {:>3} / {}",
            variant.name(),
            r.x.zero_columns(0.0).len(),
            y.cols()
        );
    }

    // --- 6. Thresholds drive feature masks (what the SAE trainer does) --
    let r = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
    let kept = r.thresholds.iter().filter(|&&u| u > 0.0).count();
    println!("\nClipping thresholds u (Remark III.2): {kept} features kept,");
    println!(
        "sum(u) = {:.6} = eta; the SAE trainer masks features with u = 0.",
        r.thresholds.iter().sum::<f64>()
    );
}
