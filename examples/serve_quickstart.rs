//! The projection service engine in five minutes.
//!
//! ```bash
//! cargo run --release --example serve_quickstart
//! ```
//!
//! Starts a sharded engine, round-trips single requests (showing the
//! threshold cache warming up), fans out a mixed async workload across
//! every projection kind, checks the served results against direct library
//! calls, and prints the per-shard telemetry.

use bilevel_sparse::config::ServeConfig;
use bilevel_sparse::norms::l1inf_norm;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::serve::{Engine, Payload, ProjectionRequest};
use bilevel_sparse::tensor::Matrix;

fn main() {
    // A small engine: 2 shards, opportunistic batching, 32-entry cache.
    let cfg = ServeConfig { shards: 2, cache_capacity: 32, ..ServeConfig::default() };
    let engine = Engine::start(&cfg).expect("engine start");
    let mut rng = Xoshiro256pp::seed_from_u64(7);

    // --- 1. one request / one response ---------------------------------
    let y = Matrix::<f64>::randn(200, 100, &mut rng);
    let eta = 5.0;
    let req = ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, eta, y.clone());
    let resp = engine.submit_wait(req.clone()).expect("submit");
    let Payload::F64(x) = &resp.payload else { unreachable!("dtype preserved") };
    println!("BP^(1,inf) via the engine:");
    println!("  ||Y||_1inf   = {:.3} -> {:.3}  (eta = {eta})", l1inf_norm(&y), l1inf_norm(x));
    println!(
        "  shard {} | batch {} | cache hit {} | queued {} us | exec {} us",
        resp.shard, resp.batch_size, resp.cache_hit, resp.queue_micros, resp.exec_micros
    );

    // --- 2. the same request again: threshold-cache hit ----------------
    let warm = engine.submit_wait(req).expect("submit");
    println!(
        "\nrepeat request: cache hit = {} (exec {} us, cold was {} us)",
        warm.cache_hit, warm.exec_micros, resp.exec_micros
    );

    // --- 3. async fan-out over every projection kind -------------------
    let kinds = ProjectionKind::all();
    let mut jobs = Vec::new();
    for i in 0..32 {
        let kind = kinds[i % kinds.len()];
        let m = Matrix::<f64>::randn(64, 48, &mut rng);
        let handle = engine
            .submit(ProjectionRequest::f64(kind, 2.0, m.clone()))
            .expect("submit");
        jobs.push((kind, m, handle));
    }
    let mut mismatches = 0;
    for (kind, m, handle) in jobs {
        let resp = handle.wait().expect("response");
        let direct = kind.apply(&m, 2.0);
        let Payload::F64(x) = &resp.payload else { unreachable!("dtype preserved") };
        if x.max_abs_diff(&direct) != 0.0 {
            mismatches += 1;
        }
    }
    println!(
        "\nmixed workload: 32 requests over {} kinds, {} mismatches vs direct library calls",
        kinds.len(),
        mismatches
    );

    // --- 4. telemetry ---------------------------------------------------
    println!();
    print!("{}", engine.shutdown());
}
