//! Projection timing comparison — a Fig.-1-style table at the terminal.
//!
//! ```bash
//! cargo run --release --example projection_bench            # full sweep
//! cargo run --release --example projection_bench -- --quick
//! ```

use anyhow::{anyhow, Result};
use bilevel_sparse::bench::{fit_linear, fit_nlogn, time_fn, BenchConfig};
use bilevel_sparse::cli::Args;
use bilevel_sparse::projection::bilevel::bilevel_l1inf;
use bilevel_sparse::projection::l1inf::{project_l1inf, L1InfAlgorithm};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::tensor::Matrix;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow!(e))?;
    let quick = args.flag("quick") || args.subcommand == "--quick";
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let sizes: Vec<usize> = if quick {
        vec![250, 500, 1000, 2000]
    } else {
        vec![500, 1000, 2000, 4000, 8000]
    };

    println!("projection timing, n = 1000 samples, eta = 1 (paper Fig. 1 setting)\n");
    println!("{:>9} {:>14} {:>14} {:>14} {:>14} {:>8}",
             "features", "bilevel", "ssn (Chu)", "newton (Chau)", "quattoni", "speedup");

    let mut xs = Vec::new();
    let mut t_bp = Vec::new();
    let mut t_ssn = Vec::new();
    for &m in &sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(m as u64);
        let y = Matrix::<f64>::randn(1000, m, &mut rng);
        let bp = time_fn(&cfg, || bilevel_l1inf(&y, 1.0)).median;
        let ssn = time_fn(&cfg, || project_l1inf(&y, 1.0, L1InfAlgorithm::Ssn)).median;
        let newton = time_fn(&cfg, || project_l1inf(&y, 1.0, L1InfAlgorithm::Newton)).median;
        let quattoni = time_fn(&cfg, || project_l1inf(&y, 1.0, L1InfAlgorithm::Quattoni)).median;
        println!(
            "{m:>9} {:>11.3} ms {:>11.3} ms {:>11.3} ms {:>11.3} ms {:>7.1}x",
            bp * 1e3,
            ssn * 1e3,
            newton * 1e3,
            quattoni * 1e3,
            ssn / bp
        );
        xs.push(m as f64);
        t_bp.push(bp);
        t_ssn.push(ssn);
    }

    let (_, _, r2_lin) = fit_linear(&xs, &t_bp);
    let (_, _, r2_nlogn) = fit_nlogn(&xs, &t_ssn);
    println!("\nbilevel ~ linear fit      R2 = {r2_lin:.5}");
    println!("ssn     ~ n log n fit     R2 = {r2_nlogn:.5}");
    println!("\n(the full sweep with CSV output: `bilevel experiment fig1`)");
    Ok(())
}
