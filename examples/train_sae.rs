//! **End-to-end driver** (DESIGN.md §Validation): train the sparse
//! supervised autoencoder through all three layers — Rust coordinator →
//! PJRT-compiled JAX train step → Pallas projection kernel — on a real
//! small workload, logging the loss curve and final metrics.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_sae -- --preset synth64 --eta 1.0
//! cargo run --release --example train_sae -- --preset tiny --epochs 3   # smoke
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::{anyhow, Result};
use bilevel_sparse::cli::Args;
use bilevel_sparse::config::{DatasetKind, ProjectionBackend, TrainConfig};
use bilevel_sparse::coordinator::SaeTrainer;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::runtime::Runtime;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "train_sae"))
        .map_err(|e| anyhow!(e))?;
    // Args::parse treats the first bare token as subcommand; re-add if used
    // as the preset by mistake.
    let preset = args.str_or(
        "preset",
        if args.subcommand.is_empty() { "synth64" } else { &args.subcommand },
    );
    let dataset = DatasetKind::parse(&preset)
        .ok_or_else(|| anyhow!("unknown --preset {preset} (synth64|synth16|hif2|tiny)"))?;
    let epochs = args.usize_or("epochs", 0).map_err(|e| anyhow!(e))?;
    // Per-preset defaults (tiny has 48 train samples: it needs a larger lr
    // and a looser radius than the 1000-feature presets).
    let (def_eta, def_lr) = match dataset {
        DatasetKind::Tiny => (2.0, 5e-3),
        DatasetKind::Hif2 => (0.25, 1e-3),
        _ => (1.0, 1e-3),
    };
    let cfg = TrainConfig {
        dataset,
        projection: ProjectionKind::BilevelL1Inf,
        backend: ProjectionBackend::parse(&args.str_or("backend", "pallas")).unwrap(),
        eta: args.f64_or("eta", def_eta).map_err(|e| anyhow!(e))?,
        epochs_phase1: if epochs > 0 { epochs } else { 15 },
        epochs_phase2: if epochs > 0 { epochs } else { 10 },
        lr: args.f64_or("lr", def_lr).map_err(|e| anyhow!(e))?,
        ..TrainConfig::default()
    };

    println!("=== end-to-end SAE training ===");
    println!(
        "dataset {} | projection {} via {} backend | eta {} | epochs {}+{}",
        cfg.dataset.name(),
        cfg.projection.name(),
        cfg.backend.name(),
        cfg.eta,
        cfg.epochs_phase1,
        cfg.epochs_phase2
    );

    let rt = Runtime::open(&args.str_or("artifacts-dir", "artifacts"))?;
    println!("PJRT platform: {}\n", rt.platform());
    let trainer = SaeTrainer::new(&rt, cfg)?;
    let seed = args.usize_or("seed", 42).map_err(|e| anyhow!(e))? as u64;
    let out = trainer.run(seed)?;

    println!("phase epoch   loss    train-acc  test-acc  alive-features");
    for h in &out.history {
        println!(
            "  {}    {:>3}   {:>7.4}   {:>6.2} %   {:>6.2} %   {:>6}",
            h.phase,
            h.epoch,
            h.train_loss,
            h.train_accuracy * 100.0,
            h.test_accuracy * 100.0,
            h.alive_features
        );
    }
    println!("\nfinal accuracy : {:.2} % (best {:.2} %)", out.final_accuracy * 100.0, out.best_accuracy * 100.0);
    println!("sparsity       : {:.1} % of features suppressed", out.sparsity_percent);
    println!("selected       : {} features", out.selected_features.len());
    println!("wallclock      : {:.1} s", out.train_seconds);

    // Sanity: training must have learned something beyond chance.
    if out.best_accuracy < 0.6 {
        return Err(anyhow!("end-to-end run failed to learn (best acc {:.2})", out.best_accuracy));
    }
    println!("\nOK: all three layers composed (coordinator -> PJRT train step -> Pallas projection).");
    Ok(())
}
