//! Feature selection on the HIF2-sim single-cell screen (paper §VI's first
//! application: "feature selection in biology").
//!
//! Trains the sparse SAE on the simulated CRISPRi data, reads the selected
//! features off the projected first layer, and — because the simulator
//! knows the ground truth — scores the recovery (precision@k) against the
//! truly informative genes. This is exactly what cannot be done with the
//! real HIF2 data and is the point of the simulator substitution.
//!
//! ```bash
//! cargo run --release --example feature_selection             # full hif2-sim
//! cargo run --release --example feature_selection -- --quick  # tiny smoke
//! ```

use anyhow::{anyhow, Result};
use bilevel_sparse::cli::Args;
use bilevel_sparse::config::{DatasetKind, TrainConfig};
use bilevel_sparse::coordinator::SaeTrainer;
use bilevel_sparse::metrics::precision_at_k;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::runtime::Runtime;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow!(e))?;
    let quick = args.flag("quick") || args.subcommand == "--quick";
    let dataset = if quick { DatasetKind::Tiny } else { DatasetKind::Hif2 };
    let cfg = TrainConfig {
        dataset,
        projection: ProjectionKind::BilevelL1Inf,
        eta: args.f64_or("eta", if quick { 2.0 } else { 0.25 }).map_err(|e| anyhow!(e))?,
        epochs_phase1: if quick { 8 } else { 12 },
        epochs_phase2: if quick { 5 } else { 8 },
        lr: if quick { 5e-3 } else { 1e-3 },
        ..TrainConfig::default()
    };
    println!(
        "feature selection on {} (eta = {}, bilevel l1,inf projection)",
        cfg.dataset.name(),
        cfg.eta
    );

    let rt = Runtime::open(&args.str_or("artifacts-dir", "artifacts"))?;
    let trainer = SaeTrainer::new(&rt, cfg)?;
    let ds = trainer.make_dataset(42);
    println!(
        "dataset: {} cells x {} genes, {} truly informative",
        ds.n_samples,
        ds.n_features,
        ds.informative.len()
    );

    let out = trainer.run(42)?;
    println!(
        "\ntrained: accuracy {:.2} %, {} / {} genes selected ({:.1} % suppressed)",
        out.final_accuracy * 100.0,
        out.selected_features.len(),
        ds.n_features,
        out.sparsity_percent
    );

    // Rank surviving genes by their W1 row norms.
    let dims = out.dims;
    let scores: Vec<f64> = (0..dims.features)
        .map(|f| {
            out.w1[f * dims.hidden..(f + 1) * dims.hidden]
                .iter()
                .fold(0.0f32, |m, &v| m.max(v.abs())) as f64
        })
        .collect();

    let k = ds.informative.len();
    let p_at_k = precision_at_k(&scores, &ds.informative, k);
    let p_at_2k = precision_at_k(&scores, &ds.informative, 2 * k);
    println!("\nground-truth recovery (simulator oracle):");
    println!("  precision@{k}  = {:.2}  (random baseline {:.4})", p_at_k, k as f64 / ds.n_features as f64);
    println!("  precision@{}  = {:.2}", 2 * k, p_at_2k);

    let mut top: Vec<usize> = (0..scores.len()).collect();
    top.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    println!("  top-10 genes: {:?}", &top[..10.min(top.len())]);
    println!("  informative : {:?}", &ds.informative[..10.min(ds.informative.len())]);

    let random_baseline = k as f64 / ds.n_features as f64;
    if p_at_k < random_baseline * 3.0 {
        return Err(anyhow!(
            "feature selection barely beats chance (p@k {p_at_k:.3} vs random {random_baseline:.3})"
        ));
    }
    println!("\nOK: selected genes are strongly enriched for the informative set.");
    Ok(())
}
