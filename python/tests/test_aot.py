"""AOT pipeline smoke tests: lowering emits parseable HLO text + manifest."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model


def test_tiny_preset_lowers(tmp_path):
    manifest = []
    aot.lower_artifacts(aot.PRESETS["tiny"], str(tmp_path), manifest)
    files = sorted(os.listdir(tmp_path))
    assert files == [
        "tiny_eval.hlo.txt",
        "tiny_project.hlo.txt",
        "tiny_train_epoch.hlo.txt",
        "tiny_train_step.hlo.txt",
    ]
    for f in files:
        text = (tmp_path / f).read_text()
        assert text.startswith("HloModule"), f
        assert "ENTRY" in text, f
    # manifest entries: one per artifact, terminated by ---
    assert len(manifest) == 4
    for entry in manifest:
        assert "file=" in entry and entry.endswith("---")


def test_hlo_text_has_no_serialized_proto_markers(tmp_path):
    # Guard against regressions to .serialize() (binary) output.
    manifest = []
    aot.lower_artifacts(aot.PRESETS["tiny"], str(tmp_path), manifest)
    text = (tmp_path / "tiny_train_step.hlo.txt").read_text()
    assert text.isprintable() or "\n" in text
    # 30 parameters (24 param/moment tensors + step, x, y, mask, lr, alpha)
    # on the train-step ENTRY computation; nested fusion computations have
    # their own parameters, so scope the count to ENTRY.
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(29)") == 1
    assert entry.count("parameter(30)") == 0


def test_project_artifact_contains_expected_ops(tmp_path):
    manifest = []
    aot.lower_artifacts(aot.PRESETS["tiny"], str(tmp_path), manifest)
    text = (tmp_path / "tiny_project.hlo.txt").read_text()
    # The bilevel projection lowers to sort (inner l1) + clamp/minimum ops.
    assert "sort" in text
    assert "minimum" in text


def test_cli_main_runs(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--presets", "tiny"],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(env["PYTHONPATH"]) or ".",
    )
    assert r.returncode == 0, r.stderr
    assert (tmp_path / "manifest.txt").exists()
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "artifact=tiny_train_step" in manifest
    assert "features=64" in manifest


def test_lowered_train_step_executes_in_jax(tmp_path):
    # The lowered computation must be executable (compile check) — run the
    # jitted flat function on concrete values as a proxy.
    p = aot.PRESETS["tiny"]
    shapes = model.SaeShapes(p.features, p.hidden, p.classes).param_shapes()
    key = jax.random.PRNGKey(0)
    params = []
    for s in shapes:
        key, sub = jax.random.split(key)
        params.append(jax.random.normal(sub, s, dtype=jnp.float32) * 0.05)
    zeros = [jnp.zeros_like(q) for q in params]
    x = jax.random.normal(key, (p.batch, p.features), dtype=jnp.float32)
    y = jax.nn.one_hot(jnp.zeros((p.batch,), dtype=jnp.int32), p.classes, dtype=jnp.float32)
    mask = jnp.ones((p.features,), dtype=jnp.float32)
    out = jax.jit(model.flat_train_step)(
        *params, *zeros, *zeros, jnp.float32(0.0), x, y, mask,
        jnp.float32(1e-3), jnp.float32(1.0),
    )
    assert len(out) == 26
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in out)
