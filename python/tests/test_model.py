"""Layer-2 model tests: shapes, loss decrease, Adam, masking, epoch scan."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model


F, H, K, B = 40, 12, 2, 16


def init_params(seed=0, f=F, h=H, k=K):
    key = jax.random.PRNGKey(seed)
    shapes = model.SaeShapes(f, h, k).param_shapes()
    params = []
    for i, s in enumerate(shapes):
        key, sub = jax.random.split(key)
        scale = 0.1 if len(s) == 2 else 0.0
        params.append(jax.random.normal(sub, s, dtype=jnp.float32) * scale)
    return tuple(params)


def batch(seed=1, b=B, f=F, k=K):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (b, f), dtype=jnp.float32)
    labels = jax.random.randint(k2, (b,), 0, k)
    y = jax.nn.one_hot(labels, k, dtype=jnp.float32)
    return x, y


def zeros_like_params(params):
    return tuple(jnp.zeros_like(p) for p in params)


def test_forward_shapes():
    params = init_params()
    x, _ = batch()
    z, xhat, h = model.forward(params, x)
    assert z.shape == (B, K)
    assert xhat.shape == (B, F)
    assert h.shape == (B, H)


def test_loss_is_finite_and_positive():
    params = init_params()
    x, y = batch()
    loss = model.total_loss(params, x, y, 1.0)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


def test_train_step_decreases_loss():
    params = init_params()
    m = zeros_like_params(params)
    v = zeros_like_params(params)
    x, y = batch()
    mask = jnp.ones((F,), dtype=jnp.float32)
    loss0 = float(model.total_loss(params, x, y, 1.0))
    step = jnp.float32(0.0)
    for _ in range(30):
        params, m, v, loss, nc = model.train_step(
            params, m, v, step, x, y, mask, jnp.float32(1e-2), jnp.float32(1.0)
        )
        step = step + 1.0
    loss1 = float(model.total_loss(params, x, y, 1.0))
    assert loss1 < loss0 * 0.9, f"loss did not decrease: {loss0} -> {loss1}"


def test_mask_zeroes_and_keeps_w1_rows():
    params = init_params()
    m = zeros_like_params(params)
    v = zeros_like_params(params)
    x, y = batch()
    mask = jnp.ones((F,), dtype=jnp.float32).at[:10].set(0.0)
    step = jnp.float32(0.0)
    for _ in range(5):
        params, m, v, loss, nc = model.train_step(
            params, m, v, step, x, y, mask, jnp.float32(1e-2), jnp.float32(1.0)
        )
        step = step + 1.0
    w1 = np.asarray(params[0])
    assert np.all(w1[:10] == 0.0), "masked rows must stay zero"
    assert np.any(w1[10:] != 0.0)


def test_train_epoch_equals_sequential_steps():
    params = init_params()
    m = zeros_like_params(params)
    v = zeros_like_params(params)
    nb = 3
    xs = jnp.stack([batch(seed=10 + i)[0] for i in range(nb)])
    ys = jnp.stack([batch(seed=10 + i)[1] for i in range(nb)])
    mask = jnp.ones((F,), dtype=jnp.float32)
    lr, alpha = jnp.float32(1e-2), jnp.float32(0.7)

    # epoch path
    pe, me, ve, step_e, loss_e, nc_e = model.train_epoch(
        params, m, v, jnp.float32(0.0), xs, ys, mask, lr, alpha
    )
    # sequential path
    ps, ms, vs = params, m, v
    step = jnp.float32(0.0)
    losses, ncs = [], []
    for i in range(nb):
        ps, ms, vs, loss, nc = model.train_step(ps, ms, vs, step, xs[i], ys[i], mask, lr, alpha)
        step = step + 1.0
        losses.append(float(loss))
        ncs.append(float(nc))
    for a, b in zip(pe, ps):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)
    assert abs(float(loss_e) - np.mean(losses)) < 1e-4
    assert abs(float(nc_e) - np.sum(ncs)) < 1e-3
    assert float(step_e) == nb


def test_adam_bias_correction_first_step():
    # After one step from zero moments, update direction = -lr * sign-ish(g).
    params = (jnp.array([1.0], dtype=jnp.float32),)
    grads = (jnp.array([2.0], dtype=jnp.float32),)
    m = (jnp.zeros(1, dtype=jnp.float32),)
    v = (jnp.zeros(1, dtype=jnp.float32),)
    new_p, _, _ = model.adam_update(params, grads, m, v, jnp.float32(1.0), jnp.float32(0.1))
    # mhat = g, vhat = g^2 -> update = lr * g/|g| = 0.1
    assert_allclose(np.asarray(new_p[0]), np.array([0.9], dtype=np.float32), rtol=1e-4)


def test_project_w1_through_pallas():
    from compile.kernels import ref

    w1 = init_params()[0] * 10.0
    eta = jnp.float32(1.5)
    x, u = model.project_w1(w1, eta)
    want = ref.bilevel_l1inf_rows(w1, eta)
    assert_allclose(np.asarray(x), np.asarray(want), rtol=1e-5, atol=1e-6)
    assert abs(float(jnp.sum(u)) - 1.5) < 1e-4


def test_flat_wrappers_roundtrip():
    params = init_params()
    m = zeros_like_params(params)
    v = zeros_like_params(params)
    x, y = batch()
    mask = jnp.ones((F,), dtype=jnp.float32)
    out = model.flat_train_step(
        *params, *m, *v, jnp.float32(0.0), x, y, mask, jnp.float32(1e-3), jnp.float32(1.0)
    )
    assert len(out) == 26
    z, xhat = model.flat_eval(*params, x)
    assert z.shape == (B, K) and xhat.shape == (B, F)


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, -1.0], [0.5, 0.5]], dtype=jnp.float32)
    y = jnp.array([[1.0, 0.0], [0.0, 1.0]], dtype=jnp.float32)
    got = float(model.cross_entropy(y, logits))
    p = jax.nn.softmax(logits)
    want = float(-jnp.mean(jnp.log(jnp.array([p[0, 0], p[1, 1]]))))
    assert abs(got - want) < 1e-6


def test_huber_quadratic_and_linear_regions():
    x = jnp.zeros((1, 2), dtype=jnp.float32)
    xhat = jnp.array([[0.5, 3.0]], dtype=jnp.float32)
    got = float(model.huber(x, xhat))
    want = (0.5 * 0.25 + (3.0 - 0.5)) / 2.0
    assert abs(got - want) < 1e-6
