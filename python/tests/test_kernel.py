"""Pallas kernels vs the pure-jnp oracle — THE core L1 correctness signal.

Hypothesis sweeps shapes, scales and radii; every case asserts
``assert_allclose`` between the kernel path (`kernels.bilevel`) and the
oracle (`kernels.ref`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import bilevel as bk
from compile.kernels import ref


def randmat(rows, cols, seed, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (rows, cols), dtype=jnp.float32) * scale


# ------------------------------------------------------------ row max

@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_row_abs_max_matches_jnp(rows, cols, seed):
    w = randmat(rows, cols, seed)
    got = bk.row_abs_max(w)
    want = jnp.max(jnp.abs(w), axis=1)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_row_abs_max_unpadded_tile_boundary():
    # rows exactly at / just past the tile boundary
    for rows in (127, 128, 129, 256):
        w = randmat(rows, 16, rows)
        got = bk.row_abs_max(w)
        want = jnp.max(jnp.abs(w), axis=1)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# --------------------------------------------------------------- clip

@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_clip_rows_matches_formula(rows, cols, seed):
    w = randmat(rows, cols, seed)
    key = jax.random.PRNGKey(seed + 1)
    u = jnp.abs(jax.random.normal(key, (rows,), dtype=jnp.float32))
    got = bk.clip_rows(w, u)
    want = jnp.sign(w) * jnp.minimum(jnp.abs(w), u[:, None])
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ------------------------------------------------- bilevel projection

@given(
    rows=st.integers(1, 200),
    cols=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
    eta_frac=st.floats(0.01, 1.2),
    scale=st.sampled_from([0.1, 1.0, 10.0, 100.0]),
)
@settings(max_examples=40, deadline=None)
def test_bilevel_rows_kernel_vs_ref(rows, cols, seed, eta_frac, scale):
    w = randmat(rows, cols, seed, scale)
    norm = float(jnp.sum(jnp.max(jnp.abs(w), axis=1)))
    eta = jnp.float32(max(eta_frac * norm, 1e-6))
    got = bk.bilevel_l1inf_rows(w, eta)
    want = ref.bilevel_l1inf_rows(w, eta)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_bilevel_rows_feasibility_and_identity():
    w = randmat(150, 32, 7, scale=5.0)
    norm0 = float(jnp.sum(jnp.max(jnp.abs(w), axis=1)))
    eta = jnp.float32(norm0 * 0.25)
    x = bk.bilevel_l1inf_rows(w, eta)
    norm1 = float(jnp.sum(jnp.max(jnp.abs(x), axis=1)))
    assert norm1 <= float(eta) * (1 + 1e-5)
    # identity (Prop. III.3), row-grouped form
    resid = w - x
    lhs = float(jnp.sum(jnp.max(jnp.abs(resid), axis=1))) + norm1
    assert abs(lhs - norm0) < 1e-3 * norm0


def test_bilevel_thresholds_bound_rows():
    w = randmat(90, 20, 11)
    eta = jnp.float32(2.0)
    x, u = bk.bilevel_l1inf_rows_with_thresholds(w, eta)
    v = jnp.max(jnp.abs(w), axis=1)
    assert np.all(np.asarray(u) >= -1e-7)
    assert np.all(np.asarray(u) <= np.asarray(v) + 1e-6)
    assert abs(float(jnp.sum(u)) - 2.0) < 1e-4  # tight when outside the ball


def test_bilevel_cols_equals_rows_of_transpose():
    y = randmat(64, 48, 13)
    eta = jnp.float32(3.0)
    a = bk.bilevel_l1inf_cols(y, eta)
    b = bk.bilevel_l1inf_rows(y.T, eta).T
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_zero_eta_zeroes_matrix():
    w = randmat(40, 10, 17)
    x = bk.bilevel_l1inf_rows(w, jnp.float32(0.0))
    assert float(jnp.max(jnp.abs(x))) == 0.0


def test_inside_ball_is_identity():
    w = randmat(40, 10, 19) * 0.01
    norm = float(jnp.sum(jnp.max(jnp.abs(w), axis=1)))
    x = bk.bilevel_l1inf_rows(w, jnp.float32(norm * 2))
    assert_allclose(np.asarray(x), np.asarray(w), rtol=1e-6)


# --------------------------------------------------- dense-silu kernel

@given(
    b=st.integers(1, 32),
    fin=st.integers(1, 64),
    fout=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_dense_silu_matches_jnp(b, fin, fout, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, fin), dtype=jnp.float32)
    w = jax.random.normal(k2, (fin, fout), dtype=jnp.float32) * 0.1
    bias = jax.random.normal(k3, (fout,), dtype=jnp.float32)
    got = bk.dense_silu(x, w, bias)
    pre = x @ w + bias
    want = pre * jax.nn.sigmoid(pre)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------ oracle self-checks

@given(
    n=st.integers(1, 400),
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(0.01, 0.95),
)
@settings(max_examples=30, deadline=None)
def test_ref_project_l1_radius(n, seed, frac):
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (n,), dtype=jnp.float32) * 3.0
    norm = float(jnp.sum(jnp.abs(v)))
    eta = jnp.float32(max(frac * norm, 1e-6))
    x = ref.project_l1(v, eta)
    got = float(jnp.sum(jnp.abs(x)))
    assert got <= float(eta) * (1 + 1e-4) + 1e-5
    if norm > float(eta):
        assert abs(got - float(eta)) < 1e-3 * (1 + float(eta))


def test_ref_identities_all_variants():
    y = randmat(60, 25, 23, scale=2.0)
    for proj, norm_fn in [
        (ref.bilevel_l1inf, ref.l1inf_norm),
        (ref.bilevel_l11, ref.l11_norm),
        (ref.bilevel_l12, ref.l12_norm),
    ]:
        total = float(norm_fn(y))
        eta = jnp.float32(total * 0.3)
        x = proj(y, eta)
        lhs = float(norm_fn(y - x)) + float(norm_fn(x))
        assert abs(lhs - total) < 1e-3 * total, proj.__name__


def test_ref_l1_matches_rust_convention():
    # Fixed case cross-checked with the Rust sort-based implementation:
    # a = [3, 1], radius 2 -> tau = 1 -> x = [2, 0].
    x = ref.project_l1(jnp.array([3.0, 1.0], dtype=jnp.float32), jnp.float32(2.0))
    assert_allclose(np.asarray(x), np.array([2.0, 0.0], dtype=np.float32), atol=1e-6)


@pytest.mark.parametrize("rows,cols", [(1, 1), (1, 17), (129, 1)])
def test_degenerate_shapes(rows, cols):
    w = randmat(rows, cols, 29)
    eta = jnp.float32(0.5)
    got = bk.bilevel_l1inf_rows(w, eta)
    want = ref.bilevel_l1inf_rows(w, eta)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
