"""AOT pipeline: lower the Layer-2 JAX functions to HLO **text** artifacts.

Run once by ``make artifacts``; Python never appears on the training path.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` Rust crate) rejects; the text
parser reassigns ids and round-trips cleanly.

Each preset emits:

* ``{preset}_train_step.hlo.txt``  — one projected-Adam step;
* ``{preset}_train_epoch.hlo.txt`` — a full epoch via ``lax.scan``;
* ``{preset}_eval.hlo.txt``        — logits + reconstruction for a batch;
* ``{preset}_project.hlo.txt``     — Pallas ``BP^{1,inf}`` on W1;

plus a ``manifest.txt`` describing every artifact (shape metadata the Rust
runtime parses — a deliberately trivial ``key=value`` format, no JSON
dependency offline).
"""

from __future__ import annotations

import argparse
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


@dataclass(frozen=True)
class Preset:
    name: str
    features: int
    hidden: int
    classes: int
    batch: int
    epoch_batches: int  # NB for the lax.scan epoch artifact
    eval_batch: int


PRESETS = {
    # Paper §V.B/C synthetic sets: n=1000 samples, m=1000 features.
    "synth": Preset("synth", 1000, 100, 2, 64, 12, 256),
    # HIF2-sim: 779 cells x 10,000 genes (paper §V.C.2).
    "hif2": Preset("hif2", 10_000, 100, 2, 32, 19, 256),
    # Tiny preset for integration tests (fast to compile & run).
    "tiny": Preset("tiny", 64, 16, 2, 8, 4, 16),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def specs_params(p: Preset):
    shapes = model.SaeShapes(p.features, p.hidden, p.classes).param_shapes()
    return [f32(*s) for s in shapes]


def lower_artifacts(p: Preset, outdir: str, manifest: list[str]) -> None:
    params = specs_params(p)
    scalar = f32()
    x = f32(p.batch, p.features)
    y = f32(p.batch, p.classes)
    xs = f32(p.epoch_batches, p.batch, p.features)
    ys = f32(p.epoch_batches, p.batch, p.classes)
    mask = f32(p.features)
    xe = f32(p.eval_batch, p.features)
    w1 = f32(p.features, p.hidden)

    def emit(kind: str, fn, args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{p.name}_{kind}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        manifest.append(
            "\n".join(
                [
                    f"artifact={p.name}_{kind}",
                    f"file={fname}",
                    f"kind={kind}",
                    f"preset={p.name}",
                    f"features={p.features}",
                    f"hidden={p.hidden}",
                    f"classes={p.classes}",
                    f"batch={p.batch}",
                    f"epoch_batches={p.epoch_batches}",
                    f"eval_batch={p.eval_batch}",
                    "---",
                ]
            )
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    # 30 inputs: params(8) m(8) v(8) step x y mask lr alpha
    emit(
        "train_step",
        model.flat_train_step,
        [*params, *params, *params, scalar, x, y, mask, scalar, scalar],
    )
    emit(
        "train_epoch",
        model.flat_train_epoch,
        [*params, *params, *params, scalar, xs, ys, mask, scalar, scalar],
    )
    emit("eval", model.flat_eval, [*params, xe])
    emit("project", model.flat_project, [w1, scalar])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--presets",
        default="tiny,synth,hif2",
        help="comma-separated preset names",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: list[str] = []
    for name in args.presets.split(","):
        p = PRESETS[name.strip()]
        print(f"preset {p.name}: F={p.features} H={p.hidden} K={p.classes} B={p.batch}")
        lower_artifacts(p, args.out, manifest)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} entries -> {args.out}/manifest.txt")


if __name__ == "__main__":
    main()
