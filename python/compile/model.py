"""Layer-2: the supervised autoencoder (SAE) of paper §V.C, in JAX.

Architecture (paper: "fully connected neural network with only one hidden
layer (dimension 100) and a latent layer of dimension k = number of
classes", SiLU activations):

    encoder:  x (B,F) --silu(W1,b1)--> h (B,H) --(W2,b2)--> z (B,K)
    decoder:  z       --silu(W3,b3)--> h'(B,H) --(W4,b4)--> x̂ (B,F)

Loss (paper eq. 28): ``phi = alpha * Huber(x, x̂) + CE(y, z)`` — the latent
layer doubles as the classification logits.

Optimizer: hand-rolled Adam (no optax in the build image). The feature mask
of the double-descent scheme multiplies the rows of ``W1`` after each
update, so masked features can never re-grow.

Everything here is **build-time only**: ``aot.py`` lowers `train_step`,
`train_epoch` (lax.scan over pre-batched data — one host round-trip per
epoch instead of per step), `eval_batch` and `project_w1` to HLO text that
the Rust runtime executes via PJRT.

Parameter flattening order (the Rust coordinator indexes by this):
    w1, b1, w2, b2, w3, b3, w4, b4
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import bilevel as bk

PARAM_NAMES = ("w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4")

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
HUBER_DELTA = 1.0


class SaeShapes(NamedTuple):
    """Static shape configuration of one SAE variant."""

    features: int
    hidden: int
    classes: int

    def param_shapes(self):
        f, h, k = self.features, self.hidden, self.classes
        return (
            (f, h), (h,),   # w1, b1
            (h, k), (k,),   # w2, b2
            (k, h), (h,),   # w3, b3
            (h, f), (f,),   # w4, b4
        )


# ------------------------------------------------------------- forward

def silu(x):
    return x * jax.nn.sigmoid(x)


def forward(params, x):
    """Returns (logits z, reconstruction x̂, hidden h)."""
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    h = silu(x @ w1 + b1)
    z = h @ w2 + b2
    hd = silu(z @ w3 + b3)
    xhat = hd @ w4 + b4
    return z, xhat, h


def huber(x, xhat, delta=HUBER_DELTA):
    """Smooth-l1 (Huber) reconstruction loss, mean over all entries."""
    d = xhat - x
    a = jnp.abs(d)
    quad = 0.5 * d * d
    lin = delta * (a - 0.5 * delta)
    return jnp.mean(jnp.where(a <= delta, quad, lin))


def cross_entropy(y_onehot, logits):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def total_loss(params, x, y_onehot, alpha):
    z, xhat, _ = forward(params, x)
    return alpha * huber(x, xhat) + cross_entropy(y_onehot, z)


def n_correct(logits, y_onehot):
    return jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y_onehot, axis=-1)).astype(jnp.float32)
    )


# ---------------------------------------------------------------- adam

def adam_update(params, grads, m, v, step, lr):
    """One Adam step; `step` is the 1-based iteration count (f32 scalar)."""
    b1c = 1.0 - ADAM_B1 ** step
    b2c = 1.0 - ADAM_B2 ** step
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / b1c
        vhat = vi / b2c
        new_params.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return tuple(new_params), tuple(new_m), tuple(new_v)


def apply_feature_mask(params, mask):
    """Zero the rows of W1 belonging to masked-out features (mask in {0,1},
    shape (F,)). Keeps masked features dead through training."""
    params = list(params)
    params[0] = params[0] * mask[:, None]
    return tuple(params)


# ---------------------------------------------------------- train steps

def train_step(params, m, v, step, x, y_onehot, mask, lr, alpha):
    """One projected/masked Adam step.

    Returns (params', m', v', loss, n_correct). `step` is the iteration
    count BEFORE this step (so bias correction uses step+1).
    """
    loss, grads = jax.value_and_grad(total_loss)(params, x, y_onehot, alpha)
    params, m, v = adam_update(params, grads, m, v, step + 1.0, lr)
    params = apply_feature_mask(params, mask)
    z, _, _ = forward(params, x)
    return params, m, v, loss, n_correct(z, y_onehot)


def train_epoch(params, m, v, step, xs, ys, mask, lr, alpha):
    """`lax.scan` over pre-batched data: xs (NB,B,F), ys (NB,B,K).

    One PJRT dispatch per epoch — the L2 optimization recorded in
    EXPERIMENTS.md §Perf. Returns (params', m', v', step', mean_loss,
    total_correct).
    """

    def body(carry, batch):
        params, m, v, step = carry
        x, y = batch
        params, m, v, loss, nc = train_step(params, m, v, step, x, y, mask, lr, alpha)
        return (params, m, v, step + 1.0), (loss, nc)

    (params, m, v, step), (losses, ncs) = jax.lax.scan(body, (params, m, v, step), (xs, ys))
    return params, m, v, step, jnp.mean(losses), jnp.sum(ncs)


def eval_batch(params, x):
    """Inference: logits + reconstruction for one padded batch."""
    z, xhat, _ = forward(params, x)
    return z, xhat


def project_w1(w1, eta):
    """`BP^{1,inf}` on the first-layer weights (rows = features) through the
    Pallas kernel; returns the projected matrix and the thresholds."""
    return bk.bilevel_l1inf_rows_with_thresholds(w1, eta)


# ------------------------------------------------------- flat wrappers
# HLO interfaces take/return flat positional tensors in PARAM_NAMES order.

def flat_train_step(*args):
    """args = 8 params, 8 m, 8 v, step, x, y, mask, lr, alpha (30 tensors).
    returns 8 params, 8 m, 8 v, loss, n_correct (26 tensors)."""
    params = tuple(args[0:8])
    m = tuple(args[8:16])
    v = tuple(args[16:24])
    step, x, y, mask, lr, alpha = args[24:]
    params, m, v, loss, nc = train_step(params, m, v, step, x, y, mask, lr, alpha)
    return (*params, *m, *v, loss, nc)


def flat_train_epoch(*args):
    """args = 8 params, 8 m, 8 v, step, xs, ys, mask, lr, alpha.
    returns 8 params, 8 m, 8 v, step', mean_loss, total_correct."""
    params = tuple(args[0:8])
    m = tuple(args[8:16])
    v = tuple(args[16:24])
    step, xs, ys, mask, lr, alpha = args[24:]
    params, m, v, step, loss, nc = train_epoch(params, m, v, step, xs, ys, mask, lr, alpha)
    return (*params, *m, *v, step, loss, nc)


def flat_eval(*args):
    """args = 8 params, x. returns logits, xhat."""
    params = tuple(args[0:8])
    return eval_batch(params, args[8])


def flat_project(w1, eta):
    return project_w1(w1, eta)
