"""Layer-1 Pallas kernels for the bi-level ℓ1,∞ projection (paper Alg. 1).

The projection is memory-bound and column/row-structured, so the TPU
mapping (DESIGN.md §Hardware-Adaptation) is:

* **pass 1** — grid over row tiles of ``W (F, H)``: each program reduces
  its ``(TILE_F, H)`` block to per-row |·|max on the VPU, writing a
  ``TILE_F`` slice of the norm vector ``v``. VMEM per program =
  ``TILE_F*H*4`` bytes (128*128*4 = 64 KiB — comfortably inside the
  ~16 MiB VMEM budget, leaving room for double buffering).
* **inner** — the m-vector ℓ1 projection runs as plain jnp between the two
  pallas calls (it is O(F) work on a tiny vector; on TPU it lives in one
  core's VMEM).
* **pass 2** — grid over the same row tiles: clip each row at its
  threshold ``u_i`` (broadcast over the lane dimension).

HBM traffic = 2 reads + 1 write of the matrix ⇒ the kernel is
bandwidth-roofline-bound, which is exactly the O(nm) claim of the paper.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ``ref.py`` by pytest, and the
lowered HLO is what ships to the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Row-tile size: multiple of the 8-sublane VPU tile; 128 matches the MXU
# edge so the same tiling feeds the SAE matmuls.
TILE_F = 128


def _pad_rows(w: jnp.ndarray, tile: int) -> tuple[jnp.ndarray, int]:
    """Pad rows up to a multiple of ``tile`` (zeros never win a |max|)."""
    f = w.shape[0]
    pad = (-f) % tile
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    return w, f


# ------------------------------------------------------- pass 1: row max

def _rowmax_kernel(w_ref, out_ref):
    """|·|max over the lane (hidden) dimension for one row tile."""
    out_ref[...] = jnp.max(jnp.abs(w_ref[...]), axis=1)


def row_abs_max(w: jnp.ndarray, *, tile: int = TILE_F) -> jnp.ndarray:
    """Per-row infinity norms of ``w`` via a tiled Pallas reduction."""
    wp, f = _pad_rows(w, tile)
    grid = (wp.shape[0] // tile,)
    out = pl.pallas_call(
        _rowmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, wp.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((wp.shape[0],), w.dtype),
        interpret=True,
    )(wp)
    return out[:f]


# ------------------------------------------------------- pass 2: clip

def _clip_kernel(w_ref, u_ref, out_ref):
    """Clip each row of the tile at its threshold (paper eq. 13)."""
    w = w_ref[...]
    u = u_ref[...]
    out_ref[...] = jnp.sign(w) * jnp.minimum(jnp.abs(w), u[:, None])


def clip_rows(w: jnp.ndarray, u: jnp.ndarray, *, tile: int = TILE_F) -> jnp.ndarray:
    """``X_ij = sign(W_ij) * min(|W_ij|, u_i)`` via a tiled Pallas kernel."""
    wp, f = _pad_rows(w, tile)
    up = jnp.pad(u, (0, wp.shape[0] - u.shape[0]))
    grid = (wp.shape[0] // tile,)
    out = pl.pallas_call(
        _clip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, wp.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile, wp.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(wp.shape, w.dtype),
        interpret=True,
    )(wp, up)
    return out[:f]


# --------------------------------------------------- full bi-level kernel

@functools.partial(jax.jit, static_argnames=("tile",))
def bilevel_l1inf_rows(w: jnp.ndarray, eta, *, tile: int = TILE_F) -> jnp.ndarray:
    """Paper Algorithm 1 on row groups: Pallas pass 1 → jnp inner ℓ1 →
    Pallas pass 2. Semantically identical to ``ref.bilevel_l1inf_rows``."""
    v = row_abs_max(w, tile=tile)
    u = ref.project_l1(v, eta)
    return clip_rows(w, u, tile=tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def bilevel_l1inf_rows_with_thresholds(
    w: jnp.ndarray, eta, *, tile: int = TILE_F
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Projection plus the threshold vector ``u`` (the trainer derives the
    feature mask from ``u == 0``)."""
    v = row_abs_max(w, tile=tile)
    u = ref.project_l1(v, eta)
    return clip_rows(w, u, tile=tile), u


def bilevel_l1inf_cols(y: jnp.ndarray, eta, *, tile: int = TILE_F) -> jnp.ndarray:
    """Column-grouped variant (the paper's matrix convention)."""
    return bilevel_l1inf_rows(y.T, eta, tile=tile).T


# ------------------------------------------------ fused dense + SiLU

def _dense_silu_kernel(x_ref, w_ref, b_ref, out_ref):
    """One (batch-tile × out-tile) block of ``silu(x @ w + b)``.

    MXU-shaped matmul with the activation fused into the same VMEM
    round-trip — the SAE encoder/decoder hot block.
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    out_ref[...] = acc * jax.nn.sigmoid(acc)


def dense_silu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """``silu(x @ w + b)`` as a single-block Pallas call (shapes in this
    repo are small enough for one block; grid-tiled for larger ones)."""
    bsz, fin = x.shape
    fout = w.shape[1]
    return pl.pallas_call(
        _dense_silu_kernel,
        in_specs=[
            pl.BlockSpec((bsz, fin), lambda: (0, 0)),
            pl.BlockSpec((fin, fout), lambda: (0, 0)),
            pl.BlockSpec((fout,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((bsz, fout), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, fout), jnp.float32),
        interpret=True,
    )(x, w, b)
