"""Pure-jnp oracle implementations (Layer-1 correctness references).

Every Pallas kernel in this package is validated against these functions by
``python/tests/``. They mirror the Rust library exactly (same algorithms,
same conventions) so the three layers can be cross-checked:

* :func:`project_l1` — sort-based l1-ball projection (Held et al.), the
  golden threshold rule;
* :func:`bilevel_l1inf` / :func:`bilevel_l11` / :func:`bilevel_l12` — the
  paper's Algorithms 1-3 over *column* groups;
* :func:`bilevel_l1inf_rows` — the row-grouped variant used on SAE weights
  ``W1`` of shape ``(features, hidden)`` where each **row** is a feature;
* norms matching ``rust/src/norms``.

All functions are jit-able, but they are *build/test-time only* — never on
the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------- norms

def l1inf_norm(y: jnp.ndarray) -> jnp.ndarray:
    """``sum_j max_i |Y_ij|`` (paper eq. 1). Columns are axis-0 slices."""
    return jnp.sum(jnp.max(jnp.abs(y), axis=0))


def linf1_norm(y: jnp.ndarray) -> jnp.ndarray:
    """``max_j sum_i |Y_ij|`` (paper eq. 4)."""
    return jnp.max(jnp.sum(jnp.abs(y), axis=0))


def l11_norm(y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(y))


def l12_norm(y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.linalg.norm(y, axis=0))


# ---------------------------------------------------- l1-ball projection

def _simplex_threshold(a: jnp.ndarray, radius) -> jnp.ndarray:
    """Waterline ``tau`` with ``sum(max(a - tau, 0)) == radius``.

    ``a`` must be non-negative with ``sum(a) > radius`` (callers guard the
    trivial cases). Sort-based, O(n log n): fine for an oracle.
    """
    s = jnp.sort(a)[::-1]
    cum = jnp.cumsum(s)
    ks = jnp.arange(1, a.shape[0] + 1, dtype=a.dtype)
    taus = (cum - radius) / ks
    # Largest k with tau_k < s_k (the active-set size).
    k = jnp.maximum(jnp.sum(taus < s), 1)
    tau = taus[k - 1]
    return jnp.maximum(tau, jnp.zeros_like(tau))


def project_l1(v: jnp.ndarray, radius) -> jnp.ndarray:
    """Projection of a vector onto the l1 ball of the given radius."""
    a = jnp.abs(v)
    inside = jnp.sum(a) <= radius
    tau = jnp.where(inside, 0.0, _simplex_threshold(a, radius))
    return jnp.sign(v) * jnp.maximum(a - tau, 0.0)


def project_linf(v: jnp.ndarray, radius) -> jnp.ndarray:
    """Clip to the linf ball (paper eq. 13)."""
    return jnp.sign(v) * jnp.minimum(jnp.abs(v), radius)


def project_l2(v: jnp.ndarray, radius) -> jnp.ndarray:
    """Radial rescale onto the l2 ball."""
    n = jnp.linalg.norm(v)
    scale = jnp.where(n > radius, radius / jnp.maximum(n, 1e-30), 1.0)
    return v * scale


# ------------------------------------------------- bi-level projections

def bilevel_l1inf(y: jnp.ndarray, eta) -> jnp.ndarray:
    """Paper Algorithm 1 over columns of ``y`` (axis 0 = within-column)."""
    v = jnp.max(jnp.abs(y), axis=0)           # column inf-norms
    u = project_l1(v, eta)                    # inner l1 projection
    return jnp.sign(y) * jnp.minimum(jnp.abs(y), u[None, :])


def bilevel_l1inf_thresholds(y: jnp.ndarray, eta) -> jnp.ndarray:
    """The inner-stage thresholds ``u`` of Algorithm 1 (for mask building)."""
    v = jnp.max(jnp.abs(y), axis=0)
    return project_l1(v, eta)


def bilevel_l11(y: jnp.ndarray, eta) -> jnp.ndarray:
    """Paper Algorithm 2: inner l1 on column l1-norms, outer per-column
    soft-thresholding."""
    v = jnp.sum(jnp.abs(y), axis=0)
    u = project_l1(v, eta)

    def col_project(col, r):
        a = jnp.abs(col)
        inside = jnp.sum(a) <= r
        tau = jnp.where(
            inside,
            0.0,
            _simplex_threshold(a, jnp.maximum(r, 1e-30)),
        )
        # r == 0 must zero the column: threshold at max|col|.
        tau = jnp.where(r <= 0, jnp.max(a), tau)
        return jnp.sign(col) * jnp.maximum(a - tau, 0.0)

    return jax.vmap(col_project, in_axes=(1, 0), out_axes=1)(y, u)


def bilevel_l12(y: jnp.ndarray, eta) -> jnp.ndarray:
    """Paper Algorithm 3: inner l1 on column l2-norms, outer rescale."""
    v = jnp.linalg.norm(y, axis=0)
    u = project_l1(v, eta)
    scale = jnp.where(v > u, u / jnp.maximum(v, 1e-30), 1.0)
    return y * scale[None, :]


# --------------------------------- row-grouped variant for SAE weights

def bilevel_l1inf_rows(w: jnp.ndarray, eta) -> jnp.ndarray:
    """``BP^{1,inf}`` with **rows** as groups.

    The SAE's first-layer weight ``W1`` has shape ``(features, hidden)``;
    feature *i* owns row *i*. Identical to ``bilevel_l1inf(w.T, eta).T``
    but kept explicit because this is the exact orientation the Pallas
    kernel and the Rust trainer use.
    """
    v = jnp.max(jnp.abs(w), axis=1)           # per-row inf-norms
    u = project_l1(v, eta)
    return jnp.sign(w) * jnp.minimum(jnp.abs(w), u[:, None])


def bilevel_l1inf_rows_thresholds(w: jnp.ndarray, eta) -> jnp.ndarray:
    v = jnp.max(jnp.abs(w), axis=1)
    return project_l1(v, eta)
