"""Layer-1 Pallas kernels + their pure-jnp oracle (`ref`)."""

from . import bilevel, ref  # noqa: F401
